"""JAX inference engine: one hosted model, continuous-batching decode.

This is the Cortex Platform "Inference Engine" (paper §2) adapted to TPU:

  * **continuous batching** (default for pure-attention decoders): fixed
    [max_batch] slots over a paged KV cache; finished sequences retire at
    EOS and queued work is admitted at *every* decode step, with long
    prompts chunk-prefilled between steps (``inference/continuous.py``).
    SCORE and COMPLETE ride this path; CLASSIFY/EMBED (single forward
    passes) and non-attention architectures use the static path below,
    with **bit-identical results** either way;
  * static-shape batch fallback: one blocking prefill+decode call per
    batch, finished sequences retiring early from the decode loop;
  * bucketed prefill (power-of-two lengths) and bucketed decode batch
    sizes to bound recompilation;
  * four request kinds: COMPLETE (greedy decode), SCORE (yes/no confidence
    from next-token logits — the cascade's s_i, §5.2), CLASSIFY
    (label-likelihood scoring over a candidate set — AI_CLASSIFY), EMBED
    (masked mean-pooled hidden states projected to the requested
    dimensionality — the semantic index's vectors, priced per input
    token on the embedding tier);
  * per-request credit metering (AI credits, §4) and latency accounting;
  * fault injection (EngineFailure) so the scheduler's retry/straggler
    logic is testable.

Modality frontends are stubs per the assignment: FILE inputs are mapped to
deterministic pseudo-embeddings derived from the URI hash.
"""
from __future__ import annotations

import hashlib
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.inference import tokenizer as tok
from repro.inference.backend import (CLASSIFY, COMPLETE, EMBED, SCORE,
                                     EngineFailure, Request, Result,
                                     credits_for)
from repro.models import model_zoo


def _bucket(n: int, lo: int = 32) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _hash_embed(key: str, shape, scale=0.1) -> np.ndarray:
    seed = int.from_bytes(hashlib.sha256(key.encode()).digest()[:4], "little")
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


class JaxInferenceEngine:
    """Hosts one model and serves batched requests."""

    def __init__(self, arch: str, *, engine_id: str = "", smoke: bool = True,
                 max_batch: int = 8, max_seq: int = 384, seed: int = 0,
                 failure_rate: float = 0.0, straggle_s: float = 0.0,
                 backend: str = "auto", block_size: int = 32,
                 kv_blocks: Optional[int] = None, prefill_chunk: int = 32,
                 decode_impl: str = "auto"):
        from repro.inference import continuous as cb
        self.arch = arch
        self.engine_id = engine_id or f"{arch}#0"
        self.model = model_zoo.build(arch, smoke=smoke)
        self.cfg = self.model.cfg
        assert self.cfg.vocab_size >= tok.VOCAB_SIZE
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.failure_rate = failure_rate
        self.straggle_s = straggle_s
        self._rng = np.random.default_rng(seed + 17)
        self.params = self.model.init_params(jax.random.PRNGKey(seed))
        self._jit_cache: Dict[Any, Any] = {}
        self.jit_compiles = 0      # distinct jit entries (compile proxy)
        # decode backend: continuous batching wherever the architecture
        # supports a paged cache, unless explicitly pinned
        if backend == "auto":
            backend = "continuous" if cb.supports(self.cfg) else "static"
        elif backend == "continuous" and not cb.supports(self.cfg):
            raise ValueError(f"{arch}: architecture does not support the "
                             "continuous paged-KV backend")
        elif backend not in ("continuous", "static"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self._batcher = None
        if backend == "continuous":
            self._batcher = cb.ContinuousBatcher(
                self, block_size=block_size, num_blocks=kv_blocks,
                prefill_chunk=prefill_chunk, decode_impl=decode_impl)
        # telemetry
        self.total_requests = 0
        self.total_tokens = 0
        self.total_credits = 0.0

    # ------------------------------------------------------------------
    # batching helpers
    # ------------------------------------------------------------------

    def _encode_batch(self, prompts: Sequence[str], cap: int
                      ) -> Tuple[np.ndarray, np.ndarray, int]:
        enc = [tok.encode(p, max_len=cap) for p in prompts]
        lens = np.asarray([len(e) for e in enc], np.int32)
        L = _bucket(int(lens.max()))
        L = min(L, cap)
        toks = np.full((len(enc), L), tok.PAD_ID, np.int32)
        for i, e in enumerate(enc):
            toks[i, :len(e)] = e[:L]
        return toks, np.minimum(lens, L), L

    def _modality_batch(self, requests: Sequence[Request], B: int,
                        S: int) -> Dict[str, np.ndarray]:
        extra: Dict[str, np.ndarray] = {}
        cfg = self.cfg
        if cfg.frontend == "frames":
            frames = np.stack([
                _hash_embed(r.metadata.get("file", r.prompt)[:128],
                            (cfg.encoder_seq, cfg.d_model))
                for r in requests])
            extra["frames"] = frames
        if cfg.frontend == "patches":
            P = min(cfg.num_patches, 16)  # smoke-scale patch count
            patches = np.stack([
                _hash_embed(r.metadata.get("file", r.prompt)[:128],
                            (P, cfg.d_model)) for r in requests])
            extra["patches"] = patches
            side = max(int(np.sqrt(P)), 1)
            pos = np.zeros((B, P + S, 3), np.int32)
            ar = np.arange(P)
            pos[:, :P, 0] = 0
            pos[:, :P, 1] = ar // side
            pos[:, :P, 2] = ar % side
            pos[:, P:, :] = (np.arange(S)[None, :, None] + 1)
            extra["positions"] = pos
        return extra

    def _jit(self, key, fn, donate=()):
        if key not in self._jit_cache:
            self.jit_compiles += 1
            self._jit_cache[key] = jax.jit(fn, donate_argnums=donate)
        return self._jit_cache[key]

    def _prefill(self, requests: Sequence[Request], cap: Optional[int] = None,
                 extra_capacity: int = 0):
        cap = cap or self.max_seq
        toks, lens, L = self._encode_batch([r.prompt for r in requests], cap)
        B = len(requests)
        extra = self._modality_batch(requests, B, L)
        smax = L + extra_capacity

        def prefill_fn(params, tokens, lengths, extra):
            cache = self.model.init_cache(tokens.shape[0], smax)
            batch = {"tokens": tokens, "lengths": lengths, **extra}
            out = self.model.apply(params, batch, mode="prefill", cache=cache)
            logits = self.model.logits_of(params, out["last_hidden"])
            return logits, out["cache"]

        fn = self._jit(("prefill", B, L, smax, tuple(sorted(extra))),
                       prefill_fn)
        logits, cache = fn(self.params, jnp.asarray(toks), jnp.asarray(lens),
                           {k: jnp.asarray(v) for k, v in extra.items()})
        return logits, cache, lens, L

    # ------------------------------------------------------------------
    # request kinds
    # ------------------------------------------------------------------

    def _score_batch(self, requests: Sequence[Request],
                     t0: Optional[float] = None) -> List[Result]:
        t0 = time.perf_counter() if t0 is None else t0
        logits, _, lens, _ = self._prefill(requests)
        lf = np.asarray(logits, np.float32)
        py = lf[:, tok.YES_ID]
        pn = lf[:, tok.NO_ID]
        score = 1.0 / (1.0 + np.exp(-(py - pn)))   # P(yes | {yes,no})
        lat = time.perf_counter() - t0
        return [
            Result(r.request_id, self.arch, SCORE, score=float(score[i]),
                   tokens_in=int(lens[i]),
                   credits=credits_for(self.arch, int(lens[i])),
                   latency_s=lat, engine_id=self.engine_id)
            for i, r in enumerate(requests)]

    def _classify_batch(self, requests: Sequence[Request],
                        t0: Optional[float] = None) -> List[Result]:
        """Label-likelihood classification: logprob of each candidate label
        as a continuation of the prompt, softmax over candidates."""
        t0 = time.perf_counter() if t0 is None else t0
        results = []
        flat_prompts, flat_labels, owners = [], [], []
        for i, r in enumerate(requests):
            for lb in (r.labels or ()):
                flat_prompts.append(r.prompt + "\nanswer: ")
                flat_labels.append(lb)
                owners.append(i)
        if not flat_prompts:
            # no candidate labels: still a served (and metered) request —
            # prompt tokens were shipped even though no label was scored
            out = []
            for r in requests:
                ti = len(tok.encode(r.prompt, max_len=self.max_seq))
                out.append(Result(
                    r.request_id, self.arch, CLASSIFY, label=None, labels=(),
                    tokens_in=ti, credits=credits_for(self.arch, ti),
                    engine_id=self.engine_id))
            return _stamp_latency(out, t0)
        lps, tokens_used = self._sequence_logprob(flat_prompts, flat_labels)
        per_req: Dict[int, List[Tuple[str, float]]] = {}
        for o, lb, lp in zip(owners, flat_labels, lps):
            per_req.setdefault(o, []).append((lb, lp))
        tokens_per_req: Dict[int, int] = {}
        for o, t in zip(owners, tokens_used):
            tokens_per_req[o] = tokens_per_req.get(o, 0) + t
        for i, r in enumerate(requests):
            pairs = per_req.get(i, [])
            if not pairs:
                # label-less request coalesced into a labeled batch: serve
                # (and meter) it like the all-empty early-return path
                ti = len(tok.encode(r.prompt, max_len=self.max_seq))
                results.append(Result(
                    r.request_id, self.arch, CLASSIFY, label=None, labels=(),
                    tokens_in=ti, credits=credits_for(self.arch, ti),
                    engine_id=self.engine_id))
                continue
            lbls = [p[0] for p in pairs]
            lp = np.asarray([p[1] for p in pairs])
            probs = np.exp(lp - lp.max())
            probs = probs / probs.sum()
            order = np.argsort(-probs)
            top = lbls[int(order[0])]
            chosen: Tuple[str, ...]
            if r.multi_label:
                k = len(lbls)
                thr = 1.5 / max(k, 2)
                chosen = tuple(lbls[j] for j in order if probs[j] >= thr) or (top,)
            else:
                chosen = (top,)
            ti = tokens_per_req.get(i, 0)
            results.append(Result(
                r.request_id, self.arch, CLASSIFY, label=top, labels=chosen,
                tokens_in=ti, credits=credits_for(self.arch, ti),
                engine_id=self.engine_id))
        return _stamp_latency(results, t0)

    def _sequence_logprob(self, prompts: Sequence[str],
                          continuations: Sequence[str]):
        """Mean per-token logprob of each continuation given its prompt."""
        seqs, masks = [], []
        for p, c in zip(prompts, continuations):
            pe = tok.encode(p, max_len=self.max_seq // 2)
            ce = tok.encode(c, bos=False)
            seqs.append(pe + ce)
            masks.append([0] * len(pe) + [1] * len(ce))
        L = _bucket(max(len(s) for s in seqs))
        L = min(L, self.max_seq)
        B = len(seqs)
        toks = np.full((B, L), tok.PAD_ID, np.int32)
        msk = np.zeros((B, L), np.float32)
        for i, (s, m) in enumerate(zip(seqs, masks)):
            s, m = s[:L], m[:L]
            toks[i, :len(s)] = s
            msk[i, :len(m)] = m

        def lp_fn(params, tokens, mask):
            batch = {"tokens": tokens}
            if self.cfg.frontend == "frames":
                batch["frames"] = jnp.zeros(
                    (tokens.shape[0], self.cfg.encoder_seq, self.cfg.d_model),
                    jnp.bfloat16)
            out = self.model.apply(params, batch, mode="train", remat=False)
            logits = self.model.logits_of(params, out["hidden"])
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            # hidden[t] predicts token[t+1]
            tgt = tokens[:, 1:]
            lp = jnp.take_along_axis(logp[:, :-1], tgt[..., None], -1)[..., 0]
            m = mask[:, 1:]
            return jnp.sum(lp * m, 1) / jnp.maximum(jnp.sum(m, 1), 1.0)

        fn = self._jit(("seqlp", B, L), lp_fn)
        lps = np.asarray(fn(self.params, jnp.asarray(toks), jnp.asarray(msk)))
        return lps.tolist(), [int(m.sum() + (1 - m).sum()) for m in msk]

    def _embed_batch(self, requests: Sequence[Request],
                     t0: Optional[float] = None) -> List[Result]:
        """Masked mean-pool of the final hidden states, projected to the
        requested dimensionality by a fixed seeded matrix and unit-
        normalized.  One encoder pass, no decode loop — which is why the
        EMBED tier prices input tokens only."""
        t0 = time.perf_counter() if t0 is None else t0
        toks, lens, L = self._encode_batch([r.prompt for r in requests],
                                           self.max_seq)
        B = len(requests)
        extra = self._modality_batch(requests, B, L)

        def embed_fn(params, tokens, lengths, extra):
            batch = {"tokens": tokens, **extra}
            out = self.model.apply(params, batch, mode="train", remat=False)
            h = out["hidden"].astype(jnp.float32)          # [B, L, D]
            mask = (jnp.arange(h.shape[1])[None, :]
                    < lengths[:, None]).astype(jnp.float32)
            pooled = jnp.sum(h * mask[..., None], axis=1) \
                / jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
            return pooled

        fn = self._jit(("embed", B, L, tuple(sorted(extra))), embed_fn)
        pooled = np.asarray(fn(self.params, jnp.asarray(toks),
                               jnp.asarray(lens),
                               {k: jnp.asarray(v) for k, v in extra.items()}))
        results = []
        for i, r in enumerate(requests):
            dim = int(r.metadata.get("embed_dim", 64))
            proj = _hash_embed(f"{self.arch}|embed-proj|{dim}",
                               (pooled.shape[1], dim), scale=1.0)
            v = pooled[i] @ proj
            v = v / max(float(np.linalg.norm(v)), 1e-12)
            results.append(Result(
                r.request_id, self.arch, EMBED,
                embedding=tuple(float(x) for x in v),
                tokens_in=int(lens[i]),
                credits=credits_for(self.arch, int(lens[i]), EMBED),
                engine_id=self.engine_id))
        return _stamp_latency(results, t0)

    def _complete_batch(self, requests: Sequence[Request],
                        t0: Optional[float] = None) -> List[Result]:
        """Greedy decode over batch slots; finished sequences retire early
        (the static fallback path — the continuous backend admits new work
        at every step instead of batch boundaries)."""
        t0 = time.perf_counter() if t0 is None else t0
        B0 = len(requests)
        max_new = max(r.max_tokens for r in requests)
        # bucket the decode batch to powers of two: per-row results are
        # batch-independent, so padding with sentinel rows costs nothing
        # and keeps the decode jit key count logarithmic in batch size
        Bp = _bucket(B0, lo=1)
        padded: List[Request] = list(requests) + [
            Request("", self.arch, COMPLETE, max_tokens=1)
            for _ in range(Bp - B0)]
        logits, cache, lens, L = self._prefill(
            padded, extra_capacity=_bucket(max(max_new, 1), lo=16))
        B = Bp

        def decode_fn(params, cache, tokens):
            out = self.model.apply(params, {"tokens": tokens}, mode="decode",
                                   cache=cache)
            lg = self.model.logits_of(params, out["hidden"][:, 0])
            return lg, out["cache"]

        fn = self._jit(("decode", B, cache_sig(cache)), decode_fn)
        cur = np.asarray(jnp.argmax(logits, -1), np.int32)[:, None]
        done = np.zeros(B, bool)
        outs: List[List[int]] = [[] for _ in range(B)]
        finish = [t0] * B
        for step in range(max_new):
            for i in range(B):
                if not done[i]:
                    outs[i].append(int(cur[i, 0]))
                    if cur[i, 0] == tok.EOS_ID or len(outs[i]) >= padded[i].max_tokens:
                        done[i] = True
                        finish[i] = time.perf_counter()
            if done.all():
                break
            lg, cache = fn(self.params, cache, jnp.asarray(cur))
            cur = np.asarray(jnp.argmax(lg, -1), np.int32)[:, None]
        end = time.perf_counter()
        results = []
        for i, r in enumerate(requests):
            text = tok.decode(outs[i])
            ntok = int(lens[i]) + len(outs[i])
            results.append(Result(
                r.request_id, self.arch, COMPLETE, text=text,
                tokens_in=int(lens[i]), tokens_out=len(outs[i]),
                credits=credits_for(self.arch, ntok),
                latency_s=(finish[i] if done[i] else end) - t0,
                engine_id=self.engine_id))
        return results

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def submit_batch(self, requests: Sequence[Request]) -> List[Result]:
        if self.failure_rate and self._rng.random() < self.failure_rate:
            raise EngineFailure(f"{self.engine_id}: injected fault")
        if self.straggle_s:
            time.sleep(self.straggle_s)
        t0 = time.perf_counter()
        out: List[Result] = []
        cont: List[Request] = []
        by_kind: Dict[str, List[Request]] = {}
        for r in requests:
            if self._batcher is not None and r.kind in (SCORE, COMPLETE):
                cont.append(r)
            else:
                by_kind.setdefault(r.kind, []).append(r)
        if cont:
            out.extend(self._batcher.serve(cont, t0))
        for kind, reqs in by_kind.items():
            for i in range(0, len(reqs), self.max_batch):
                chunk = reqs[i:i + self.max_batch]
                if kind == SCORE:
                    out.extend(self._score_batch(chunk, t0))
                elif kind == CLASSIFY:
                    out.extend(self._classify_batch(chunk, t0))
                elif kind == EMBED:
                    out.extend(self._embed_batch(chunk, t0))
                else:
                    out.extend(self._complete_batch(chunk, t0))
        for r in out:
            self.total_credits += r.credits
            self.total_tokens += r.tokens_in + r.tokens_out
        self.total_requests += len(requests)
        return self._restore_order(requests, out)

    def _restore_order(self, requests: Sequence[Request],
                       out: List[Result]) -> List[Result]:
        """Return results in submission order.  Duplicated request ids map
        to submission positions in production order (stable); a result
        whose id was never submitted is an engine invariant violation."""
        slots: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            slots.setdefault(r.request_id, []).append(i)
        taken: Dict[int, int] = {}
        keyed: List[Tuple[int, Result]] = []
        for res in out:
            positions = slots.get(res.request_id)
            k = taken.get(res.request_id, 0)
            if positions is None or k >= len(positions):
                raise EngineFailure(
                    f"{self.engine_id}: result for unknown request_id "
                    f"{res.request_id!r}")
            taken[res.request_id] = k + 1
            keyed.append((positions[k], res))
        keyed.sort(key=lambda t: t[0])
        return [res for _, res in keyed]

    def hosted_models(self) -> List[str]:
        return [self.arch]

    def capacity_hint(self) -> int:
        """Preferred per-dispatch batch size (scheduler right-sizing).
        The continuous backend absorbs oversized batches through per-step
        admission, so it advertises a deeper queue."""
        if self._batcher is not None:
            return self.max_batch * 4
        return self.max_batch

    def backend_stats(self) -> Dict[str, Any]:
        """Decode-backend telemetry (continuous batching + jit entries)."""
        d: Dict[str, Any] = {"backend": self.backend,
                             "jit_entries": self.jit_compiles}
        if self._batcher is not None:
            d.update(self._batcher.stats())
        return d

    def backend_roofline(self) -> Dict[str, Any]:
        """Roofline-derived utilization of the continuous backend's step
        functions (prefill vs decode), from ``launch/roofline.py``; empty
        on the static backend or before any request was served."""
        if self._batcher is None:
            return {}
        return self._batcher.roofline_report()


def _stamp_latency(results: List[Result], t0: float) -> List[Result]:
    """Chunk-level latency for single-forward-pass kinds: every request in
    the chunk finished when the chunk did (no per-request step loop to
    attribute from)."""
    lat = time.perf_counter() - t0
    for r in results:
        r.latency_s = lat
    return results


def cache_sig(cache):
    leaves = jax.tree.leaves(cache)
    return tuple((l.shape, str(l.dtype)) for l in leaves[:3])
