"""Cortex Platform API Service (paper §2): the front-end the SQL engine
talks to.  Applies business logic (request ids, budget guards, credit
metering), forwards to the Scheduler, and exposes typed convenience calls
used by the AISQL operators.
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.inference.backend import (CLASSIFY, COMPLETE, SCORE, Request,
                                     Result)
from repro.inference.scheduler import Scheduler


class CortexClient:
    """What a virtual warehouse holds: a handle to the Cortex API service."""

    def __init__(self, scheduler: Scheduler, *, default_model: str = "oracle-70b",
                 proxy_model: str = "proxy-8b"):
        self.scheduler = scheduler
        self.default_model = default_model
        self.proxy_model = proxy_model
        self._ids = itertools.count(1)
        # meters (paper §4 cost-analysis instrumentation)
        self.ai_calls = 0
        self.ai_credits = 0.0
        self.ai_seconds = 0.0
        self.calls_by_model: Dict[str, int] = {}

    # ------------------------------------------------------------------
    def _submit(self, requests: List[Request]) -> List[Result]:
        for r in requests:
            r.request_id = next(self._ids)
        results = self.scheduler.submit(requests)
        self.ai_calls += len(results)
        for res in results:
            self.ai_credits += res.credits
            self.ai_seconds += res.latency_s
            self.calls_by_model[res.model] = \
                self.calls_by_model.get(res.model, 0) + 1
        return results

    # ------------------------------------------------------------------
    def complete(self, prompts: Sequence[str], *, model: Optional[str] = None,
                 max_tokens: int = 48,
                 metadata: Optional[Sequence[Dict[str, Any]]] = None
                 ) -> List[str]:
        model = model or self.default_model
        md = metadata or [{} for _ in prompts]
        res = self._submit([
            Request(p, model, COMPLETE, max_tokens=max_tokens, metadata=m)
            for p, m in zip(prompts, md)])
        return [r.text for r in res]

    def filter_scores(self, prompts: Sequence[str], *,
                      model: Optional[str] = None,
                      metadata: Optional[Sequence[Dict[str, Any]]] = None
                      ) -> np.ndarray:
        """Confidence s_i = P(predicate true) per row (§5.2)."""
        model = model or self.default_model
        md = metadata or [{} for _ in prompts]
        res = self._submit([
            Request(p, model, SCORE, metadata=m) for p, m in zip(prompts, md)])
        return np.asarray([r.score for r in res], np.float64)

    def classify(self, prompts: Sequence[str], labels: Tuple[str, ...], *,
                 model: Optional[str] = None, multi_label: bool = False,
                 metadata: Optional[Sequence[Dict[str, Any]]] = None
                 ) -> List[Tuple[str, ...]]:
        model = model or self.default_model
        md = metadata or [{} for _ in prompts]
        res = self._submit([
            Request(p, model, CLASSIFY, labels=tuple(labels),
                    multi_label=multi_label, metadata=m)
            for p, m in zip(prompts, md)])
        return [tuple(r.labels or ((r.label,) if r.label else ())) for r in res]

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        return {"ai_calls": self.ai_calls, "ai_credits": self.ai_credits,
                "ai_seconds": self.ai_seconds,
                "calls_by_model": dict(self.calls_by_model)}

    def meter_delta(self, before: Dict[str, Any]) -> Dict[str, Any]:
        return {
            "ai_calls": self.ai_calls - before["ai_calls"],
            "ai_credits": self.ai_credits - before["ai_credits"],
            "ai_seconds": self.ai_seconds - before["ai_seconds"],
        }


def make_simulated_client(*, seed: int = 0, default_model: str = "oracle-70b",
                          proxy_model: str = "proxy-8b") -> CortexClient:
    """Convenience: a CortexClient over the calibrated simulator."""
    from repro.inference.simulator import SimulatedBackend
    sched = Scheduler()
    sched.register(SimulatedBackend(seed=seed))
    return CortexClient(sched, default_model=default_model,
                        proxy_model=proxy_model)


def make_engine_client(archs: Sequence[str] = ("proxy-8b", "oracle-70b"), *,
                       seed: int = 0, replicas: int = 1,
                       default_model: Optional[str] = None) -> CortexClient:
    """Convenience: a CortexClient over real JAX engines (smoke-size)."""
    from repro.inference.engine import JaxInferenceEngine
    sched = Scheduler()
    for arch in archs:
        for rep in range(replicas):
            sched.register(JaxInferenceEngine(
                arch, engine_id=f"{arch}#{rep}", seed=seed + rep))
    return CortexClient(sched, default_model=default_model or archs[-1],
                        proxy_model=archs[0])
