"""Cortex Platform API Service (paper §2): the front-end the SQL engine
talks to.  Applies business logic (request ids, budget guards, credit
metering), forwards to the RequestPipeline / Scheduler, and exposes typed
convenience calls used by the AISQL operators.

Two execution modes share one code path:

  * **eager** (``pipeline=None``): ``submit_async`` dispatches each batch
    immediately and returns already-resolved futures — the seed engine's
    per-call-site behaviour, bit-identical telemetry included;
  * **pipelined** (``pipeline=`` a `RequestPipeline` or `PipelineConfig`):
    ``submit_async`` enqueues into coalescing per-model queues and returns
    pending futures; work is dispatched on flush (size threshold or the
    first ``result()`` barrier), with identical requests deduplicated.

The sync convenience methods (``complete`` / ``filter_scores`` /
``classify``) are thin wrappers: submit async, then await — so legacy
callers (cascades, aggregators, notebooks) transparently ride the
pipeline's batching and memoization.

Credit metering happens **on dispatch**, not on submission: a request
served from the dedup cache costs zero AI credits, which is exactly the
saving the paper's §4 cost analysis wants surfaced.
"""
from __future__ import annotations

import itertools
import threading
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

import numpy as np

from repro.inference.backend import (CLASSIFY, COMPLETE, EMBED, SCORE,
                                     Request, Result)
from repro.inference.pipeline import (PipelineConfig, RequestPipeline,
                                      ResultFuture)
from repro.inference.scheduler import Scheduler


class CortexClient:
    """What a virtual warehouse holds: a handle to the Cortex API service.

    ``owner`` marks this client as one session of a **shared** pipeline
    (the serving runtime): its requests are tagged with the owner so the
    pipeline bills this client's meter — registered via
    ``register_meter`` — only for the dispatches this session caused,
    and ``flush()`` becomes an owner-scoped barrier that leaves other
    sessions' queued work coalescing.  Without an owner the client
    behaves exactly as before and assumes the pipeline is **private**:
    failed-query cleanup (``cancel_queued``) withdraws every owner-less
    queued item, and metering claims the pipeline-wide dispatch hook —
    so sharing one pipeline between several *owner-less* clients is
    unsupported; give each client an owner instead.
    """

    def __init__(self, scheduler: Scheduler, *, default_model: str = "oracle-70b",
                 proxy_model: str = "proxy-8b",
                 embed_model: str = "arctic-embed-m",
                 pipeline: Union[None, bool, PipelineConfig,
                                 RequestPipeline] = None,
                 owner: Optional[str] = None,
                 on_dispatch_extra: Optional[
                     Callable[[Sequence[Result]], None]] = None):
        self.scheduler = scheduler
        self.default_model = default_model
        self.proxy_model = proxy_model
        self.embed_model = embed_model
        self.owner = owner
        self._ids = itertools.count(1)
        # meters (paper §4 cost-analysis instrumentation); the lock keeps
        # them consistent when a *different* session's barrier dispatches
        # (and therefore bills) this session's coalesced requests
        self._meter_lock = threading.Lock()
        self.ai_calls = 0
        self.ai_credits = 0.0
        self.ai_seconds = 0.0
        self.calls_by_model: Dict[str, int] = {}
        if pipeline is True:
            pipeline = PipelineConfig()
        if isinstance(pipeline, PipelineConfig):
            pipeline = RequestPipeline(scheduler, pipeline,
                                       on_dispatch=self._meter)
        elif isinstance(pipeline, RequestPipeline):
            if owner is not None:
                # shared pipeline: bill through the per-owner registry,
                # never clobber the pipeline-wide hook.  One registration
                # chains the client meter with the caller's extra hook
                # (the serving engine's tenant billing).
                extra = on_dispatch_extra

                def _owner_meter(results, _extra=extra):
                    self._meter(results)
                    if _extra is not None:
                        _extra(results)

                pipeline.register_meter(owner, _owner_meter)
            else:
                pipeline.on_dispatch = self._meter
        self.pipeline: Optional[RequestPipeline] = pipeline or None

    # ------------------------------------------------------------------
    def _meter(self, results: Sequence[Result]) -> None:
        with self._meter_lock:
            self.ai_calls += len(results)
            for res in results:
                self.ai_credits += res.credits
                self.ai_seconds += res.latency_s
                self.calls_by_model[res.model] = \
                    self.calls_by_model.get(res.model, 0) + 1

    def submit_async(self, requests: List[Request]) -> List[ResultFuture]:
        """Queue requests; returns one future per request (input order)."""
        for r in requests:
            r.request_id = next(self._ids)
        if self.pipeline is not None:
            return self.pipeline.submit_many(requests, owner=self.owner)
        results = self.scheduler.submit(requests)
        self._meter(results)
        return [ResultFuture.resolved(res) for res in results]

    def flush(self) -> None:
        """Barrier: force-dispatch everything this client queued (with an
        owner, only its own items; otherwise the whole pipeline)."""
        if self.pipeline is not None:
            if self.owner is not None:
                self.pipeline.flush(owner=self.owner)
            else:
                self.pipeline.flush()

    def cancel_queued(self) -> int:
        """Withdraw every still-queued request this client exclusively
        owns (failed-query cleanup; never-billed by construction)."""
        if self.pipeline is None:
            return 0
        return self.pipeline.cancel_owner(self.owner)

    def _submit(self, requests: List[Request]) -> List[Result]:
        return [f.result() for f in self.submit_async(requests)]

    # ------------------------------------------------------------------
    def complete(self, prompts: Sequence[str], *, model: Optional[str] = None,
                 max_tokens: int = 48,
                 metadata: Optional[Sequence[Dict[str, Any]]] = None
                 ) -> List[str]:
        model = model or self.default_model
        md = metadata or [{} for _ in prompts]
        res = self._submit([
            Request(p, model, COMPLETE, max_tokens=max_tokens, metadata=m)
            for p, m in zip(prompts, md)])
        return [r.text for r in res]

    def filter_scores(self, prompts: Sequence[str], *,
                      model: Optional[str] = None,
                      metadata: Optional[Sequence[Dict[str, Any]]] = None
                      ) -> np.ndarray:
        """Confidence s_i = P(predicate true) per row (§5.2)."""
        model = model or self.default_model
        md = metadata or [{} for _ in prompts]
        res = self._submit([
            Request(p, model, SCORE, metadata=m) for p, m in zip(prompts, md)])
        return np.asarray([r.score for r in res], np.float64)

    def embed(self, texts: Sequence[str], *, model: Optional[str] = None,
              metadata: Optional[Sequence[Dict[str, Any]]] = None
              ) -> np.ndarray:
        """Unit-vector embeddings, one row per text (EMBED kind; priced
        per input token on the embedding tier).  Identical texts dedup
        through the pipeline like every other kind."""
        model = model or self.embed_model
        md = metadata or [{} for _ in texts]
        res = self._submit([
            Request(t, model, EMBED, metadata=m) for t, m in zip(texts, md)])
        return np.asarray([r.embedding for r in res], np.float32)

    def classify(self, prompts: Sequence[str], labels: Tuple[str, ...], *,
                 model: Optional[str] = None, multi_label: bool = False,
                 metadata: Optional[Sequence[Dict[str, Any]]] = None
                 ) -> List[Tuple[str, ...]]:
        model = model or self.default_model
        md = metadata or [{} for _ in prompts]
        res = self._submit([
            Request(p, model, CLASSIFY, labels=tuple(labels),
                    multi_label=multi_label, metadata=m)
            for p, m in zip(prompts, md)])
        return [tuple(r.labels or ((r.label,) if r.label else ())) for r in res]

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        with self._meter_lock:
            out = {"ai_calls": self.ai_calls, "ai_credits": self.ai_credits,
                   "ai_seconds": self.ai_seconds,
                   "calls_by_model": dict(self.calls_by_model)}
        # a shared pipeline's stats mix every session's traffic — a
        # per-query delta of them would be misleading, so only a private
        # pipeline surfaces them here (QueryReport.pipeline); read via
        # the locked snapshot so a concurrent dispatch never tears it
        if self.pipeline is not None and self.owner is None:
            out["pipeline"] = self.pipeline.stats_snapshot()
        return out

    def meter_delta(self, before: Dict[str, Any]) -> Dict[str, Any]:
        out = {
            "ai_calls": self.ai_calls - before["ai_calls"],
            "ai_credits": self.ai_credits - before["ai_credits"],
            "ai_seconds": self.ai_seconds - before["ai_seconds"],
        }
        if self.pipeline is not None and "pipeline" in before:
            out["pipeline"] = self.pipeline.stats_delta(before["pipeline"])
        return out


def _make_pipeline(pipelined: bool,
                   pipeline: Union[None, PipelineConfig, RequestPipeline]
                   ) -> Union[None, PipelineConfig, RequestPipeline]:
    if pipeline is not None:
        return pipeline
    return PipelineConfig() if pipelined else None


def make_simulated_client(*, seed: int = 0, default_model: str = "oracle-70b",
                          proxy_model: str = "proxy-8b",
                          pipelined: bool = False,
                          pipeline: Union[None, PipelineConfig,
                                          RequestPipeline] = None
                          ) -> CortexClient:
    """Convenience: a CortexClient over the calibrated simulator."""
    from repro.inference.simulator import SimulatedBackend
    sched = Scheduler()
    sched.register(SimulatedBackend(seed=seed))
    return CortexClient(sched, default_model=default_model,
                        proxy_model=proxy_model,
                        pipeline=_make_pipeline(pipelined, pipeline))


def make_engine_client(archs: Sequence[str] = ("proxy-8b", "oracle-70b"), *,
                       seed: int = 0, replicas: int = 1,
                       default_model: Optional[str] = None,
                       pipelined: bool = False,
                       pipeline: Union[None, PipelineConfig,
                                       RequestPipeline] = None,
                       backend: str = "auto") -> CortexClient:
    """Convenience: a CortexClient over real JAX engines (smoke-size).
    ``backend`` pins the engines' decode backend ("auto" picks continuous
    batching wherever the architecture supports the paged KV cache)."""
    from repro.inference.engine import JaxInferenceEngine
    sched = Scheduler()
    for arch in archs:
        for rep in range(replicas):
            sched.register(JaxInferenceEngine(
                arch, engine_id=f"{arch}#{rep}", seed=seed + rep,
                backend=backend))
    return CortexClient(sched, default_model=default_model or archs[-1],
                        proxy_model=archs[0],
                        pipeline=_make_pipeline(pipelined, pipeline))
