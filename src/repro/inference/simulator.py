"""Calibrated simulated backend for quality experiments.

The cascade (§6.2) and join-rewrite (§6.3) evaluations need ground-truth
labels and a *realistic proxy-confidence distribution*; with no network
access the HuggingFace datasets are recreated synthetically (repro.data)
and this backend plays the role of the LLMs:

  * SCORE:  s_i ~ Beta mixture conditioned on the true label.  The mixture
    parameters are per-"dataset difficulty" (passed in request metadata),
    calibrated so proxy-only accuracy lands where the paper's Table 2 puts
    Llama-3.1-8B, and oracle error rates where Llama-3.3-70B lands.
  * CLASSIFY: the model answers correctly with prob (1 - err); errors are
    drawn from the remaining candidates.  Multi-label adds per-label
    drop/add noise — reproducing the precision/recall trade-offs of §6.3.
  * COMPLETE: template completion (used for AI_AGG/SUMMARIZE text paths).
  * EMBED: deterministic topic-correlated unit vectors — word-bag anchor
    mixtures by default, ground-truth-anchored when the request metadata
    carries ``truth_labels`` / ``embed_anchor`` (the semantic-index
    analogue of the SCORE path's ``truth``).  Billed at the per-kind
    embedding rate through the same meters, and fault-injectable like
    every other kind (the fault die rolls before any request is served).

Latency/cost model: per-request latency = base + tokens * per_token, with
constants measured from the real JAX engine and scaled by model size, so
simulated "execution time" stays tied to compute reality.  Determinism:
every random draw is keyed by (seed, request fingerprint).
"""
from __future__ import annotations

import hashlib
import re
import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.inference.backend import (CLASSIFY, COMPLETE, EMBED, SCORE,
                                     EngineFailure, EngineTimeout, Request,
                                     Result, credits_for)

# model quality/latency profiles: (error_rate_scale, seconds per 1k tokens)
# latency constants derive from bf16 FLOPs at 197 TFLOP/s/chip with 60% MFU
# over 8 chips — the per-model ratios are what matters for speedup numbers.
MODEL_PROFILES: Dict[str, Dict[str, float]] = {
    "proxy-8b": {"err_scale": 1.0, "s_per_ktok": 0.017},
    "oracle-70b": {"err_scale": 0.28, "s_per_ktok": 0.149},
    "minitron-8b": {"err_scale": 1.0, "s_per_ktok": 0.017},
    "qwen3-32b": {"err_scale": 0.45, "s_per_ktok": 0.068},
    "command-r-35b": {"err_scale": 0.42, "s_per_ktok": 0.074},
    "stablelm-12b": {"err_scale": 0.8, "s_per_ktok": 0.026},
    "recurrentgemma-9b": {"err_scale": 0.95, "s_per_ktok": 0.019},
    "phi3.5-moe-42b-a6.6b": {"err_scale": 0.55, "s_per_ktok": 0.014},
    "qwen2-moe-a2.7b": {"err_scale": 1.2, "s_per_ktok": 0.006},
    "qwen2-vl-7b": {"err_scale": 0.9, "s_per_ktok": 0.080},
    "rwkv6-1.6b": {"err_scale": 1.5, "s_per_ktok": 0.004},
    "whisper-base": {"err_scale": 1.0, "s_per_ktok": 0.002},
    # EMBED-class models: a single encoder pass, no decode loop
    "arctic-embed-m": {"err_scale": 1.0, "s_per_ktok": 0.003},
    "e5-base-embed": {"err_scale": 1.0, "s_per_ktok": 0.004},
}

# default dimensionality of simulated embeddings (overridable per request
# via metadata["embed_dim"]); 64 keeps random anchors near-orthogonal
# (cos ~ N(0, 1/64)) while staying cheap for the kernel path
EMBED_DIM = 64
# Per-request overhead is model-proportional: a fixed-depth decode/launch
# cost equivalent to ~64 tokens of that model's throughput, plus a small
# model-independent scheduling constant.
BASE_OVERHEAD_TOKENS = 64
SCHED_LATENCY_S = 0.001


def _rng_for(seed: int, *parts) -> np.random.Generator:
    h = hashlib.sha256(("|".join(str(p) for p in parts)).encode()).digest()
    return np.random.default_rng([seed, int.from_bytes(h[:8], "little")])


class SimulatedBackend:
    """Drop-in InferenceBackend with calibrated quality + compute-tied cost.

    ``clock`` accumulates modelled serving seconds (batch-aware: requests in
    one submit_batch share engine throughput).

    Transient-fault injection (the production retry path's test rig):
    with ``fault_rate`` / ``timeout_rate`` > 0 each ``submit_batch`` call
    rolls a deterministic die (keyed by seed and a per-backend attempt
    counter, so retries of the same batch re-roll) and raises
    `EngineFailure` / `EngineTimeout` **before any request is served or
    billed** — a faulted batch costs nothing, so retry layers can never
    double-bill.  Result draws stay keyed by request fingerprint, so a
    successful retry returns bit-identical answers to a fault-free run.
    """

    def __init__(self, models: Optional[Sequence[str]] = None, *, seed: int = 0,
                 batch_parallelism: int = 8, fault_rate: float = 0.0,
                 timeout_rate: float = 0.0, fault_seed: Optional[int] = None,
                 fault_burst_every: int = 0, fault_burst_len: int = 0):
        self.models = list(models or MODEL_PROFILES)
        self.seed = seed
        self.batch_parallelism = batch_parallelism
        self.fault_rate = float(fault_rate)
        self.timeout_rate = float(timeout_rate)
        self.fault_seed = seed if fault_seed is None else fault_seed
        # bursty fault process (production outages cluster in time): with
        # fault_burst_every > 0 the fault/timeout die only rolls during
        # the first fault_burst_len attempts of each fault_burst_every
        # window of the attempt counter; service is clean in between
        self.fault_burst_every = int(fault_burst_every)
        self.fault_burst_len = int(fault_burst_len)
        self.clock_s = 0.0
        self.total_credits = 0.0
        self.calls_by_model: Dict[str, int] = {}
        self.faults_injected = 0
        self.timeouts_injected = 0
        self._fault_attempts = 0
        # meters and the attempt counter are mutated per submit_batch;
        # concurrent serving dispatches serialize here
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def hosted_models(self) -> List[str]:
        return list(self.models)

    def capacity_hint(self) -> int:
        """Preferred per-dispatch batch size (scheduler right-sizing):
        modelled parallel slots × a queueing factor."""
        return self.batch_parallelism * 32

    def submit_batch(self, requests: Sequence[Request]) -> List[Result]:
        with self._lock:
            return self._submit_batch_locked(requests)

    def _maybe_inject_fault(self) -> None:
        """Raise a transient failure/timeout *before* serving or billing
        anything — all-or-nothing per batch, deterministic per attempt."""
        if not (self.fault_rate or self.timeout_rate):
            return
        self._fault_attempts += 1
        if self.fault_burst_every > 0:
            phase = (self._fault_attempts - 1) % self.fault_burst_every
            if phase >= self.fault_burst_len:
                return          # between bursts: clean service
        rng = _rng_for(self.fault_seed, "fault", self._fault_attempts)
        u = rng.random()
        if u < self.fault_rate:
            self.faults_injected += 1
            raise EngineFailure(
                f"injected transient fault (attempt {self._fault_attempts})")
        if u < self.fault_rate + self.timeout_rate:
            self.timeouts_injected += 1
            raise EngineTimeout(
                f"injected timeout (attempt {self._fault_attempts})")

    def _submit_batch_locked(self, requests: Sequence[Request]
                             ) -> List[Result]:
        self._maybe_inject_fault()
        out: List[Result] = []
        batch_s = 0.0
        for r in requests:
            prof = MODEL_PROFILES.get(r.model, MODEL_PROFILES["proxy-8b"])
            ntok = max(len(r.prompt) // 4, 8)
            if r.kind == CLASSIFY and r.labels:
                ntok += sum(len(l) // 4 + 2 for l in r.labels)
            lat = (SCHED_LATENCY_S + prof["s_per_ktok"]
                   * (ntok + BASE_OVERHEAD_TOKENS) / 1e3)
            res = self._serve_one(r, prof, ntok)
            res.latency_s = lat
            res.credits = credits_for(r.model, ntok, r.kind)
            out.append(res)
            batch_s += lat
            self.total_credits += res.credits
            self.calls_by_model[r.model] = self.calls_by_model.get(r.model, 0) + 1
        # batched execution amortises across parallel slots
        self.clock_s += batch_s / self.batch_parallelism
        return out

    # ------------------------------------------------------------------
    def _serve_one(self, r: Request, prof, ntok: int) -> Result:
        rng = _rng_for(self.seed, r.model, r.kind, r.prompt)
        md = r.metadata
        if r.kind == EMBED:
            vec = self._embed(r)
            return Result(r.request_id, r.model, EMBED,
                          embedding=tuple(float(x) for x in vec),
                          tokens_in=ntok)
        if r.kind == SCORE and ("fp_bias" in md or "fn_bias" in md):
            # explicit error-bias calibration (semantic-join pair predicates):
            # a negative pair reads as positive with prob fp_bias (the
            # systematic yes-bias of isolated binary decisions, §6.3) and a
            # positive reads as negative with prob fn_bias.
            truth = bool(md.get("truth", False))
            flip = float(md.get("fn_bias", 0.0)) if truth else \
                float(md.get("fp_bias", 0.0))
            eff = truth ^ (rng.random() < flip)
            conc = 14.0
            s = rng.beta(conc, 1.0) if eff else rng.beta(1.0, conc)
            return Result(r.request_id, r.model, SCORE, score=float(s),
                          tokens_in=ntok)
        if r.kind == SCORE:
            truth = bool(md.get("truth", rng.random() < 0.5))
            difficulty = float(md.get("difficulty", 0.3))
            # hardness of this particular row (some rows are intrinsically
            # ambiguous for every model — shared via the row fingerprint)
            row_rng = _rng_for(self.seed + 1, "row", r.prompt)
            hard = row_rng.random() < difficulty
            err = difficulty * prof["err_scale"]
            if hard:
                # ambiguous rows: scores near the middle, weakly informative;
                # stronger models (lower err_scale) skew toward the truth side
                boost = (1.0 / max(prof["err_scale"], 0.3)) ** 0.5
                if truth:
                    s = rng.beta(2.2 * boost, 1.8)
                else:
                    s = rng.beta(1.8, 2.2 * boost)
            else:
                conc = 9.0 / max(prof["err_scale"], 0.2)
                s = rng.beta(conc, 1.0) if truth else rng.beta(1.0, conc)
            wrong = rng.random() < err * (0.8 if hard else 0.15)
            if wrong:
                s = 1.0 - s
            return Result(r.request_id, r.model, SCORE, score=float(s),
                          tokens_in=ntok)
        if r.kind == CLASSIFY:
            labels = list(r.labels or ())
            truth_labels = md.get("truth_labels")
            err = min(0.95, float(md.get("difficulty", 0.25)) *
                      prof["err_scale"])
            if truth_labels is None:
                chosen = [labels[rng.integers(len(labels))]] if labels else []
            elif r.multi_label and ("drop_prob" in md or "add_frac" in md):
                # explicit calibration for the §6.3 rewrite: each true label
                # is kept with prob 1-drop (conservative-selection recall
                # loss); each false candidate is added with prob add_frac
                # (comparative reasoning keeps the count low and independent
                # of the candidate-set size).  Every draw is keyed by the
                # (prompt, label) pair — not the candidate-set composition —
                # so classifying over a *subset* of the labels (the semantic
                # index's candidate pruning) returns exactly the full run's
                # decisions restricted to that subset.
                drop = float(md.get("drop_prob", 0.0))
                add = float(md.get("add_frac", 0.0))
                chosen = []
                for lb in labels:
                    lrng = _rng_for(self.seed, r.model, r.kind, r.prompt,
                                    "label", lb)
                    if lb in truth_labels:
                        if lrng.random() >= drop:
                            chosen.append(lb)
                    elif lrng.random() < add:
                        chosen.append(lb)
            elif r.multi_label:
                chosen = []
                for lb in labels:
                    if lb in truth_labels:
                        # multi-label recall penalty: conservative selection
                        keep = rng.random() > (err + float(md.get(
                            "recall_penalty", 0.0)))
                        if keep:
                            chosen.append(lb)
                    else:
                        if rng.random() < err * 0.08:
                            chosen.append(lb)
                if not chosen and labels:
                    chosen = [labels[rng.integers(len(labels))]]
            else:
                tl = [t for t in truth_labels if t in labels]
                if tl and rng.random() >= err:
                    chosen = [tl[0]]
                else:
                    pool = [l for l in labels if l not in truth_labels] or labels
                    chosen = [pool[rng.integers(len(pool))]]
            return Result(r.request_id, r.model, CLASSIFY,
                          label=(chosen[0] if chosen else None),
                          labels=tuple(chosen), tokens_in=ntok)
        # COMPLETE with an "nl2sql" grounding block: NL->AISQL
        # compilation — answer with the verified query whose question
        # matches, sometimes corrupted so the caller's validation loop
        # is exercised (a retry re-prompts with feedback, which changes
        # the rng key and usually repairs the draw)
        if md.get("nl2sql"):
            return self._serve_nl2sql(r, prof, rng, ntok)
        # COMPLETE: deterministic template text (extract/combine/summarize)
        text = md.get("canned") or _template_completion(r.prompt)
        return Result(r.request_id, r.model, COMPLETE, text=text,
                      tokens_in=ntok, tokens_out=max(len(text) // 4, 1))

    def _serve_nl2sql(self, r: Request, prof, rng, ntok: int) -> Result:
        spec = r.metadata["nl2sql"]
        question = str(spec.get("question", "")).lower()
        qtok = set(re.findall(r"[a-z0-9_]+", question))
        best_sql, best_score = "SELECT 1", -1.0
        for ex in spec.get("examples", ()):
            etok = set(re.findall(
                r"[a-z0-9_]+", str(ex.get("question", "")).lower()))
            score = len(qtok & etok) / max(len(etok), 1)
            if score > best_score:
                best_sql, best_score = str(ex.get("sql", "")), score
        err = min(0.9, float(spec.get("difficulty", 0.15))
                  * prof["err_scale"])
        sql = best_sql
        if rng.random() < err:
            # corruptions are always *invalid* SQL — either untokenizable
            # (ParseError) or referencing a column no catalog has
            # (semantic rejection) — so a query that passes validation
            # is always the grounded-truth answer
            if rng.random() < 0.5:
                sql = sql + " ???"
            else:
                sql = re.sub(r"(?i)^\s*SELECT\s",
                             "SELECT no_such_column_xx, ", sql, count=1)
        return Result(r.request_id, r.model, COMPLETE,
                      text=f"```sql\n{sql}\n```",
                      tokens_in=ntok, tokens_out=max(len(sql) // 4, 1))

    # ------------------------------------------------------------------
    # EMBED: deterministic topic-correlated unit vectors
    # ------------------------------------------------------------------

    def _anchor(self, key: str, dim: int) -> np.ndarray:
        """Fixed unit vector for a topic/label/word string — shared by
        every request (and every model), so two texts about the same
        topic land near each other in embedding space."""
        v = _rng_for(self.seed, "embed-anchor", key).standard_normal(dim)
        n = np.linalg.norm(v)
        return v / max(n, 1e-12)

    def _embed(self, r: Request) -> np.ndarray:
        """Deterministic embedding of ``r.prompt``.

        Grounding mirrors the SCORE/CLASSIFY paths: when the request's
        metadata carries ``truth_labels`` (the hidden ``_labels`` column)
        the vector is the normalized mean of those labels' anchors plus
        small noise — so a document sits close to exactly its true labels
        and the index's kNN candidates recover the ground-truth pairs.
        Without truth metadata the vector is a word-bag mixture of
        per-word anchors: texts sharing vocabulary are similar, arbitrary
        texts are near-orthogonal.  Every component is keyed by
        (seed, text), so results are bit-identical across retries and
        across the dedup cache.
        """
        md = r.metadata
        dim = int(md.get("embed_dim", EMBED_DIM))
        noise_scale = float(md.get("embed_noise", 0.05))
        anchor_key = md.get("embed_anchor")
        tl = md.get("truth_labels")
        if anchor_key is not None:
            # label/category rows: the text *is* the topic (the semantic
            # index manager marks the label side of a join this way)
            vec = self._anchor(str(anchor_key), dim)
        elif tl is not None:
            tl = list(tl) if isinstance(tl, (tuple, list, set)) else [tl]
            vec = np.zeros(dim)
            for lb in tl:
                vec += self._anchor(str(lb), dim)
        else:
            words = r.prompt.split()
            vec = np.zeros(dim)
            for w in dict.fromkeys(words):      # distinct words, kept order
                vec += self._anchor(w.lower(), dim) * words.count(w)
        vec = vec / max(np.linalg.norm(vec), 1e-12)
        noise = _rng_for(self.seed, "embed-noise",
                         r.prompt).standard_normal(dim)
        noise = noise / max(np.linalg.norm(noise), 1e-12)
        # bounded angular perturbation: noise_scale ~ radians off-axis
        vec = vec + noise_scale * noise
        return vec / max(np.linalg.norm(vec), 1e-12)


def _template_completion(prompt: str) -> str:
    head = prompt.strip().splitlines()[-1][:80] if prompt.strip() else ""
    digest = hashlib.sha256(prompt.encode()).hexdigest()[:8]
    return f"[{digest}] {head}"
