"""Inference request/result types and the backend protocol.

Everything above this line (AISQL executor, cascades, join rewrite) talks to
``InferenceBackend.submit_batch`` only — the real JAX engine and the
calibrated simulator are interchangeable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple

COMPLETE = "complete"
SCORE = "score"        # binary predicate -> confidence in [0,1]
CLASSIFY = "classify"  # choose label(s) from a candidate set
EMBED = "embed"        # text -> unit vector (the semantic index's fuel)


@dataclasses.dataclass
class Request:
    prompt: str
    model: str
    kind: str = COMPLETE
    max_tokens: int = 32
    labels: Optional[Tuple[str, ...]] = None
    multi_label: bool = False
    # opaque payload: ground-truth hooks for the simulator, routing hints…
    metadata: Dict[str, Any] = dataclasses.field(default_factory=dict)
    request_id: int = 0


@dataclasses.dataclass
class Result:
    request_id: int
    model: str
    kind: str
    text: str = ""
    score: Optional[float] = None            # SCORE kind
    label: Optional[str] = None              # CLASSIFY kind (top-1)
    labels: Optional[Tuple[str, ...]] = None  # CLASSIFY multi-label
    embedding: Optional[Tuple[float, ...]] = None  # EMBED kind (unit vector)
    tokens_in: int = 0
    tokens_out: int = 0
    credits: float = 0.0
    latency_s: float = 0.0
    engine_id: str = ""


class InferenceBackend(Protocol):
    def submit_batch(self, requests: Sequence[Request]) -> List[Result]: ...
    def hosted_models(self) -> List[str]: ...


class EngineFailure(RuntimeError):
    """Raised by an engine when a (possibly injected) fault occurs; the
    scheduler retries on a healthy replica."""


class EngineTimeout(EngineFailure):
    """An engine exceeded its serving deadline (injected via the
    simulator's ``timeout_rate``); retried exactly like a failure but
    counted separately so serving telemetry can tell them apart."""


# --- model pricing tables (credits per 1M tokens), mirrors §4's observation
# that AI credits dominate and that multimodal/oracle models cost more.
# Generative kinds (COMPLETE / SCORE / CLASSIFY) price every token the
# model processes at the model's rate:
CREDITS_PER_MTOK = {
    "proxy-8b": 0.19,
    "oracle-70b": 1.33,
    "recurrentgemma-9b": 0.22,
    "command-r-35b": 0.83,
    "qwen3-32b": 0.75,
    "stablelm-12b": 0.30,
    "minitron-8b": 0.19,
    "whisper-base": 0.06,
    "phi3.5-moe-42b-a6.6b": 0.17,   # active-param priced
    "qwen2-moe-a2.7b": 0.08,
    "qwen2-vl-7b": 0.90,            # multimodal premium (paper §5.1)
    "rwkv6-1.6b": 0.05,
}
# EMBED-class models are priced per *input* token only — there is no
# completion pass, so the rate sits an order of magnitude below even the
# proxy tier (the economics behind index-assisted pruning: an embedding
# costs ~1% of a proxy call over the same text).
EMBED_CREDITS_PER_MTOK = {
    "arctic-embed-m": 0.02,
    "e5-base-embed": 0.03,
}
_DEFAULT_CREDITS_PER_MTOK = 0.5
_DEFAULT_EMBED_CREDITS_PER_MTOK = 0.03
# request kind -> (pricing table, default rate).  Kinds absent here fall
# back to the generative table, so SCORE/CLASSIFY/COMPLETE prices are
# bit-identical to the pre-table formula.
KIND_PRICING = {
    EMBED: (EMBED_CREDITS_PER_MTOK, _DEFAULT_EMBED_CREDITS_PER_MTOK),
}


def credits_for(model: str, tokens: int, kind: Optional[str] = None) -> float:
    """Credits for processing ``tokens`` input tokens with ``model``.

    ``kind`` selects the pricing table: EMBED-class requests bill at the
    embedding rate (input tokens only, no completion tokens); every other
    kind — and the legacy two-argument call — uses the generative table.
    """
    table, default = KIND_PRICING.get(kind,
                                      (CREDITS_PER_MTOK,
                                       _DEFAULT_CREDITS_PER_MTOK))
    return table.get(model, default) * tokens / 1e6
