"""Paged KV cache: fixed-size blocks, a free-list allocator, block tables.

The continuous-batching backend (``inference/continuous.py``) cannot give
every slot a dense ``[B, Smax]`` cache — sequences of wildly different
lengths would all pay for the longest one, and a retiring sequence would
strand its whole allocation until the batch drains.  Instead the cache is
a **pool of fixed-size blocks**:

  * the pool mirrors ``model.init_cache`` leaf-for-leaf with the
    ``(batch, Smax)`` axis pair replaced by ``(num_blocks, block_size)``:
    a scanned-period leaf ``[P, B, Smax, KV, hd]`` becomes
    ``[P, NB, bs, KV, hd]`` and a tail leaf ``[B, Smax, KV, hd]`` becomes
    ``[NB, bs, KV, hd]``;
  * each live sequence owns a **block table** — the ordered list of pool
    blocks holding its tokens — allocated from a host-side free list at
    admission and returned at retirement;
  * block 0 is reserved as a sacrificial scratch block: unassigned table
    entries point at it, so gathers of empty slots read junk that is never
    trusted, and scatters of invalid positions are dropped (out-of-range
    block index + ``mode="drop"``).

``gather``/``scatter`` are pure functions (the pool is threaded through
jit as an argument), so one jitted step function can materialise the
dense per-step view, run the model, and persist only the newly valid
keys/values back into the pool.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp


class OutOfBlocks(RuntimeError):
    """Free list exhausted — the caller should defer admission."""


def _leaf_axis(shape, block_size: int) -> int:
    """Index of the (batch, seq) axis pair in an ``init_cache(1, bs)``
    leaf: the first ``i`` with ``shape[i] == 1 and shape[i+1] == bs``."""
    for i in range(len(shape) - 1):
        if shape[i] == 1 and shape[i + 1] == block_size:
            return i
    raise ValueError(
        f"cache leaf {shape} has no (batch, seq={block_size}) axis pair — "
        "architecture is not paged-cache compatible")


class PagedKVCache:
    def __init__(self, model, *, block_size: int = 32, num_blocks: int = 64):
        if block_size < 2:
            raise ValueError("block_size must be >= 2")
        if num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is scratch)")
        self.block_size = int(block_size)
        self.num_blocks = int(num_blocks)
        template = model.init_cache(1, self.block_size)

        def pool_leaf(x):
            a = _leaf_axis(x.shape, self.block_size)
            shape = (x.shape[:a] + (self.num_blocks, self.block_size)
                     + x.shape[a + 2:])
            return jnp.zeros(shape, x.dtype)

        self._axes: Dict[str, Any] = {}
        self.pool: Dict[str, Any] = {}
        for key, sub in template.items():
            if key == "len":
                continue
            self._axes[key] = jax.tree.map(
                lambda x: _leaf_axis(x.shape, self.block_size), sub)
            self.pool[key] = jax.tree.map(pool_leaf, sub)
        # LIFO free list; block 0 stays out as the sacrificial scratch block
        self._free: List[int] = list(range(self.num_blocks - 1, 0, -1))

    # ------------------------------------------------------------------
    # host-side allocator
    # ------------------------------------------------------------------

    def blocks_for(self, tokens: int) -> int:
        return max(-(-int(tokens) // self.block_size), 1)

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def max_seq_blocks(self) -> int:
        """Largest block table a single sequence can hold."""
        return self.num_blocks - 1

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise OutOfBlocks(f"need {n} blocks, {len(self._free)} free")
        return [self._free.pop() for _ in range(n)]

    def free_blocks(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            if not 0 < b < self.num_blocks:
                raise ValueError(f"bad block id {b}")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
            self._free.append(b)

    # ------------------------------------------------------------------
    # pure gather / scatter (jit-safe; pool passed explicitly)
    # ------------------------------------------------------------------

    def gather(self, pool, tables, lens):
        """Materialise the dense model cache view of ``tables``.

        pool: as ``self.pool``; tables: int32 [B, nb]; lens: int32 [B].
        Returns a ``model.init_cache``-shaped cache with Smax = nb * bs
        and ``"len" = lens``.
        """
        B, nb = tables.shape
        flat = tables.reshape(-1)

        def one(leaf, a):
            g = jnp.take(leaf, flat, axis=a)        # [..., B*nb, bs, ...]
            shp = leaf.shape
            return g.reshape(shp[:a] + (B, nb * shp[a + 1]) + shp[a + 2:])

        cache = {k: jax.tree.map(one, pool[k], self._axes[k]) for k in pool}
        cache["len"] = lens
        return cache

    def scatter(self, pool, cache, tables, start, count, width: int):
        """Persist newly written cache positions back into the pool.

        cache: dense view returned by the model, with new tokens written at
        positions ``start .. start+width-1`` per row; start/count: int32
        [B]; ``width`` is the static per-row write window (the prefill
        chunk size, or 1 for a decode step).  Only the first ``count``
        positions per row are persisted — ragged chunk tails and inactive
        slots never touch the pool.
        """
        bs = self.block_size
        B, nbw = tables.shape
        i = jnp.arange(width, dtype=jnp.int32)[None]           # [1, C]
        pos = start[:, None] + i                               # [B, C]
        valid = i < count[:, None]
        blk = jnp.take_along_axis(
            tables, jnp.clip(pos // bs, 0, nbw - 1), axis=1)   # [B, C]
        blk = jnp.where(valid, blk, self.num_blocks)           # OOB -> drop
        off = pos % bs
        bidx = jnp.arange(B, dtype=jnp.int32)[:, None]

        def core(pl, dn):
            # pl: [NB, bs, ...]; dn: [B, S, ...]
            vals = dn[bidx, jnp.clip(pos, 0, dn.shape[1] - 1)]  # [B, C, ...]
            return pl.at[blk, off].set(vals, mode="drop")

        def one(pl, dn, a):
            fn = core
            for _ in range(a):          # vmap over leading axes (periods)
                fn = jax.vmap(fn, in_axes=(0, 0))
            return fn(pl, dn)

        return {k: jax.tree.map(one, pool[k], cache[k], self._axes[k])
                for k in pool}
