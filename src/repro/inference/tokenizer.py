"""Byte-level tokenizer for the serving stack.

Vocabulary: 4 specials + 256 bytes.  Fits every zoo vocab (all >= 512) so
any hosted architecture can serve AISQL traffic.  The yes/no class tokens
used for AI_FILTER confidence scores (§5.2) are the byte tokens for 'y'/'n'.
"""
from __future__ import annotations

from typing import List, Sequence

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
SEP_ID = 3
_OFFSET = 4
VOCAB_SIZE = 256 + _OFFSET

YES_ID = ord("y") + _OFFSET
NO_ID = ord("n") + _OFFSET


def encode(text: str, *, bos: bool = True, eos: bool = False,
           max_len: int | None = None) -> List[int]:
    ids = [BOS_ID] if bos else []
    ids += [b + _OFFSET for b in text.encode("utf-8", errors="replace")]
    if eos:
        ids.append(EOS_ID)
    if max_len is not None and len(ids) > max_len:
        # keep the tail: instructions usually end the prompt
        ids = ids[:1] + ids[-(max_len - 1):] if bos else ids[-max_len:]
    return ids


def decode(ids: Sequence[int]) -> str:
    bs = bytes(i - _OFFSET for i in ids
               if _OFFSET <= i < VOCAB_SIZE)
    return bs.decode("utf-8", errors="replace")
