"""Batched async request pipeline — the semantic-operator runtime core.

Every AI call site in the engine (filters, cascades, joins, projections,
aggregations) funnels `Request`s through one `RequestPipeline` instead of
issuing blocking per-call-site scheduler submits.  The pipeline

  * **coalesces** micro-batches across chunks / predicates / operators
    into right-sized engine batches: requests accumulate in per-model
    queues and are dispatched together, so ten 50-row label chunks become
    one 500-row engine batch;
  * **deduplicates** identical work: two requests with the same
    ``(model, kind, prompt, labels, multi_label, max_tokens)`` fingerprint
    share a single engine execution.  Duplicates arriving while the
    primary is queued attach to it in-flight; duplicates arriving after it
    completed are served from a bounded memoized result cache (repeated
    prompts recur across adaptive-reorder chunks, hybrid-join passes,
    cascade escalation, and — in production — across repeated queries);
  * **meters honestly**: only dispatched requests reach the
    ``on_dispatch`` hook (the CortexClient's credit meter), so dedup
    savings show up directly in AI-credit telemetry;
  * **reports**: batch-size histogram, dedup/cache hit counts, queue-wait
    seconds, and flush causes (size vs barrier) via `PipelineStats`.

Flush policy: a model queue flushes when it reaches ``max_batch``
requests (*size*), or when any future's ``result()`` is demanded or
``flush()`` is called (*barrier*).  The synchronous harness makes futures
deterministic: forcing one unresolved future flushes every queue, so
results never deadlock and arrival order never changes query semantics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.inference.backend import Request, Result
from repro.inference.scheduler import Scheduler


def request_fingerprint(r: Request) -> Tuple:
    """Dedup key: everything that determines the engine's answer.

    Real engines answer from (model, kind, prompt, labels, max_tokens)
    alone, but the calibrated simulator also grounds results in request
    metadata (truth, difficulty, bias knobs) — so the metadata is folded
    into the key.  In every intended dedup case (re-scored rows across
    adaptive-reorder chunks, cascade escalation, repeated queries) the
    duplicate carries the same row metadata, so this only prevents
    *false* sharing between distinct rows with identical text.
    """
    md = tuple(sorted((k, str(v)) for k, v in r.metadata.items())) \
        if r.metadata else ()
    return (r.model, r.kind, r.prompt, r.labels, r.multi_label,
            r.max_tokens, md)


class ResultFuture:
    """Handle for one in-flight request.  ``result()`` forces a barrier
    flush of the owning pipeline if the request has not been dispatched.
    A future whose request was cancelled before dispatch (see
    `RequestPipeline.cancel`) raises on ``result()``."""

    __slots__ = ("_pipeline", "_result", "_cancelled")

    def __init__(self, pipeline: Optional["RequestPipeline"] = None):
        self._pipeline = pipeline
        self._result: Optional[Result] = None
        self._cancelled = False

    @classmethod
    def resolved(cls, result: Result) -> "ResultFuture":
        f = cls(None)
        f._result = result
        return f

    def done(self) -> bool:
        return self._result is not None

    def cancelled(self) -> bool:
        return self._cancelled

    def _resolve(self, result: Result) -> None:
        self._result = result

    def result(self) -> Result:
        if self._cancelled:
            raise RuntimeError("request was cancelled before dispatch")
        if self._result is None:
            if self._pipeline is None:
                raise RuntimeError("unresolved future with no pipeline")
            self._pipeline.flush()
        if self._result is None:      # pragma: no cover - defensive
            raise RuntimeError("pipeline flush did not resolve future")
        return self._result


@dataclasses.dataclass
class PipelineConfig:
    max_batch: int = 512          # flush-on-size threshold / dispatch size
    dedup: bool = True
    cache_size: int = 65536       # memoized results (FIFO eviction)


@dataclasses.dataclass
class PipelineStats:
    submitted: int = 0            # requests entering the pipeline
    dispatched: int = 0           # requests actually sent to the scheduler
    batches: int = 0              # scheduler submits issued
    dedup_hits: int = 0           # total coalesced duplicates (both kinds)
    inflight_hits: int = 0        # attached to a queued identical request
    cache_hits: int = 0           # served from the memoized result cache
    flushes_on_size: int = 0
    flushes_on_barrier: int = 0
    cancelled: int = 0            # queued requests cancelled pre-dispatch
    queue_wait_s: float = 0.0     # sum over dispatched reqs of queue time
    batch_size_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    # submissions per request kind (score/classify/complete): lets the
    # stats store / docs attribute dedup wins to operator families
    kind_hist: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def dedup_hit_rate(self) -> float:
        return self.dedup_hits / self.submitted if self.submitted else 0.0

    def snapshot(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["batch_size_hist"] = dict(self.batch_size_hist)
        return d

    def delta(self, before: Dict[str, Any]) -> Dict[str, Any]:
        """Per-query telemetry: stats accumulated since ``before``."""
        now = self.snapshot()
        out: Dict[str, Any] = {}
        for k, v in now.items():
            if isinstance(v, dict):
                prev = before.get(k, {})
                out[k] = {sz: n - prev.get(sz, 0) for sz, n in v.items()
                          if n - prev.get(sz, 0)}
            else:
                out[k] = v - before.get(k, 0)
        sub = out.get("submitted", 0)
        out["dedup_hit_rate"] = out["dedup_hits"] / sub if sub else 0.0
        return out


class _QueueItem:
    __slots__ = ("request", "futures", "enqueued_at")

    def __init__(self, request: Request, future: ResultFuture, t: float):
        self.request = request
        self.futures = [future]
        self.enqueued_at = t


class RequestPipeline:
    """Coalescing, deduplicating request queue in front of the Scheduler."""

    def __init__(self, scheduler: Scheduler,
                 cfg: Optional[PipelineConfig] = None, *,
                 on_dispatch: Optional[Callable[[List[Result]], None]] = None):
        self.scheduler = scheduler
        self.cfg = cfg or PipelineConfig()
        self.on_dispatch = on_dispatch
        self.stats = PipelineStats()
        self._queues: Dict[str, List[_QueueItem]] = {}
        self._inflight: Dict[Tuple, _QueueItem] = {}
        self._cache: Dict[Tuple, Result] = {}

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def submit(self, request: Request) -> ResultFuture:
        return self.submit_many([request])[0]

    def submit_many(self, requests: Sequence[Request]) -> List[ResultFuture]:
        now = time.perf_counter()
        futures: List[ResultFuture] = []
        touched: List[str] = []
        for r in requests:
            self.stats.submitted += 1
            self.stats.kind_hist[r.kind] = \
                self.stats.kind_hist.get(r.kind, 0) + 1
            key = request_fingerprint(r) if self.cfg.dedup else None
            if key is not None:
                cached = self._cache.get(key)
                if cached is not None:
                    self.stats.dedup_hits += 1
                    self.stats.cache_hits += 1
                    futures.append(ResultFuture.resolved(cached))
                    continue
                pending = self._inflight.get(key)
                if pending is not None:
                    f = ResultFuture(self)
                    pending.futures.append(f)
                    self.stats.dedup_hits += 1
                    self.stats.inflight_hits += 1
                    futures.append(f)
                    continue
            f = ResultFuture(self)
            item = _QueueItem(r, f, now)
            self._queues.setdefault(r.model, []).append(item)
            if key is not None:
                self._inflight[key] = item
            futures.append(f)
            touched.append(r.model)
        for model in dict.fromkeys(touched):
            if len(self._queues.get(model, ())) >= self.cfg.max_batch:
                self.stats.flushes_on_size += 1
                self._flush_model(model)
        return futures

    # ------------------------------------------------------------------
    # flushing / dispatch
    # ------------------------------------------------------------------

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def flush(self, model: Optional[str] = None) -> None:
        """Barrier: dispatch every queued request (or one model's queue)."""
        models = [model] if model is not None else list(self._queues)
        flushed_any = False
        for m in models:
            if self._queues.get(m):
                flushed_any = True
                self._flush_model(m)
        if flushed_any:
            self.stats.flushes_on_barrier += 1

    def cancel(self, futures: Sequence[ResultFuture]) -> int:
        """Cancel still-queued requests — the LIMIT-aware early-termination
        hook: a streaming consumer that has its ``n`` rows withdraws the
        speculative partitions it no longer needs *before* they are
        dispatched, so they never reach an engine or the credit meter.

        A queued request is cancelled only when **every** future attached
        to it (the original plus any dedup attachments) is in ``futures``
        — work another call site still awaits is left untouched.  Requests
        already dispatched (or resolved) cannot be cancelled.  Returns the
        number of requests removed from the queues.
        """
        want = {id(f) for f in futures}
        cancelled = 0
        for model in list(self._queues):
            kept: List[_QueueItem] = []
            for item in self._queues[model]:
                if item.futures and all(id(f) in want for f in item.futures):
                    cancelled += 1
                    for f in item.futures:
                        f._cancelled = True
                    if self.cfg.dedup:
                        self._inflight.pop(
                            request_fingerprint(item.request), None)
                else:
                    kept.append(item)
            if kept:
                self._queues[model] = kept
            else:
                del self._queues[model]
        self.stats.cancelled += cancelled
        return cancelled

    def _flush_model(self, model: str) -> None:
        size = max(self.cfg.max_batch, 1)
        queue = self._queues.get(model)
        while queue:
            # pop one chunk at a time so a dispatch failure leaves the
            # rest of the queue intact (re-flushable) instead of orphaned
            items, self._queues[model] = queue[:size], queue[size:]
            queue = self._queues[model]
            if not queue:
                self._queues.pop(model, None)
            self._dispatch(items)

    def _dispatch(self, items: List[_QueueItem]) -> None:
        if not items:
            return
        t0 = time.perf_counter()
        try:
            results = self.scheduler.submit([it.request for it in items])
        except Exception:
            # the error propagates to the caller awaiting the barrier; drop
            # the in-flight fingerprints so later identical requests don't
            # attach to these (now unreachable) queue items
            if self.cfg.dedup:
                for it in items:
                    self._inflight.pop(request_fingerprint(it.request), None)
            raise
        self.stats.batches += 1
        self.stats.dispatched += len(items)
        self.stats.batch_size_hist[len(items)] = \
            self.stats.batch_size_hist.get(len(items), 0) + 1
        if self.on_dispatch is not None:
            self.on_dispatch(results)
        for it, res in zip(items, results):
            self.stats.queue_wait_s += t0 - it.enqueued_at
            key = request_fingerprint(it.request) if self.cfg.dedup else None
            if key is not None:
                self._inflight.pop(key, None)
                self._remember(key, res)
            for f in it.futures:
                f._resolve(res)

    # ------------------------------------------------------------------
    # memoized result cache
    # ------------------------------------------------------------------

    def _remember(self, key: Tuple, result: Result) -> None:
        cap = self.cfg.cache_size
        if cap <= 0:
            return
        if len(self._cache) >= cap:
            # FIFO eviction of the oldest half (dict preserves insertion)
            for k in list(self._cache)[:max(cap // 2, 1)]:
                del self._cache[k]
        self._cache[key] = result

    def clear_cache(self) -> None:
        self._cache.clear()
