"""Batched async request pipeline — the semantic-operator runtime core.

Every AI call site in the engine (filters, cascades, joins, projections,
aggregations) funnels `Request`s through one `RequestPipeline` instead of
issuing blocking per-call-site scheduler submits.  The pipeline

  * **coalesces** micro-batches across chunks / predicates / operators
    into right-sized engine batches: requests accumulate in per-model
    queues and are dispatched together, so ten 50-row label chunks become
    one 500-row engine batch;
  * **deduplicates** identical work: two requests with the same
    ``(model, kind, prompt, labels, multi_label, max_tokens)`` fingerprint
    share a single engine execution.  Duplicates arriving while the
    primary is queued attach to it in-flight; duplicates arriving after it
    completed are served from a bounded **LRU** result cache with an
    optional TTL (repeated prompts recur across adaptive-reorder chunks,
    hybrid-join passes, cascade escalation, and — under the serving
    runtime — across concurrent queries and tenants, where a hit from a
    different session counts as a *cross-query* hit);
  * **retries transient faults**: a dispatch that fails with an
    `EngineFailure` / `SchedulerError` is re-dispatched with exponential
    backoff up to ``PipelineConfig.max_retries`` times; a request that
    exhausts its retries resolves its futures with a `RequestFailed`
    error — never a silent drop, never a hang, and never a double bill
    (metering happens only on the one successful dispatch);
  * **meters honestly**: only dispatched requests reach the
    ``on_dispatch`` hook (the CortexClient's credit meter), so dedup
    savings show up directly in AI-credit telemetry.  Under the serving
    runtime each queue item carries the **owner** (session) that caused
    it, and per-owner meters registered via `register_meter` are billed
    at dispatch — total dispatch spend always equals the sum of owner
    bills plus the default-hook bill;
  * **reports**: batch-size histogram, dedup/cache/cross-query hit
    counts, queue-wait seconds, retry/failure counts, and flush causes
    (size vs barrier) via `PipelineStats`.

Flush policy: a model queue flushes when it reaches ``max_batch``
requests (*size*), or when a future's ``result()`` is demanded or
``flush()`` is called (*barrier*).  A ``result()`` barrier is scoped to
the future's own model queue — that always resolves it, while other
models' (and other sessions') queues keep coalescing.
``flush(owner=...)`` is the serving engine's per-session barrier: it
dispatches only that owner's queued items.

Concurrency model: **single-dispatcher via one reentrant lock**.  Every
public entry point (submit, flush, cancel) acquires ``self._lock`` for
its full duration, including the engine dispatch — so queue, dedup
table, cache and stats mutations are always serialized, duplicate
futures can never attach to an item mid-resolution, and a ``result()``
call racing a dispatch simply blocks on the lock until its future is
resolved.  Concurrency wins come from coalescing and caching *across*
the querying threads, not from parallel dispatch; the backends model
batch-parallel execution internally.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.inference.backend import EngineFailure, Request, Result
from repro.inference.scheduler import Scheduler, SchedulerError
from repro.obs.metrics import locked_snapshot
from repro.obs.trace import active_tracer


class RequestFailed(RuntimeError):
    """A request exhausted the pipeline's bounded retries (or was
    cancelled before dispatch); raised by ``ResultFuture.result()``."""


def request_fingerprint(r: Request) -> Tuple:
    """Dedup key: everything that determines the engine's answer.

    Real engines answer from (model, kind, prompt, labels, max_tokens)
    alone, but the calibrated simulator also grounds results in request
    metadata (truth, difficulty, bias knobs) — so the metadata is folded
    into the key.  In every intended dedup case (re-scored rows across
    adaptive-reorder chunks, cascade escalation, repeated queries) the
    duplicate carries the same row metadata, so this only prevents
    *false* sharing between distinct rows with identical text.
    """
    md = tuple(sorted((k, str(v)) for k, v in r.metadata.items())) \
        if r.metadata else ()
    return (r.model, r.kind, r.prompt, r.labels, r.multi_label,
            r.max_tokens, md)


class ResultFuture:
    """Handle for one in-flight request.  ``result()`` forces a barrier
    flush of the owning pipeline if the request has not been dispatched.
    A future whose request was cancelled before dispatch (see
    `RequestPipeline.cancel`) or permanently failed (retries exhausted)
    raises `RequestFailed` on ``result()``."""

    __slots__ = ("_pipeline", "_result", "_cancelled", "_error", "_model")

    def __init__(self, pipeline: Optional["RequestPipeline"] = None,
                 model: Optional[str] = None):
        self._pipeline = pipeline
        self._result: Optional[Result] = None
        self._cancelled = False
        self._error: Optional[Exception] = None
        self._model = model           # scopes the barrier flush

    @classmethod
    def resolved(cls, result: Result) -> "ResultFuture":
        f = cls(None)
        f._result = result
        return f

    def done(self) -> bool:
        return self._result is not None or self._error is not None

    def cancelled(self) -> bool:
        return self._cancelled

    def exception(self) -> Optional[Exception]:
        return self._error

    def _resolve(self, result: Result) -> None:
        self._result = result

    def _fail(self, error: Exception) -> None:
        self._error = error

    def result(self) -> Result:
        if self._cancelled:
            raise RequestFailed("request was cancelled before dispatch")
        if self._error is not None:
            raise self._error
        if self._result is None:
            if self._pipeline is None:
                raise RuntimeError("unresolved future with no pipeline")
            # barrier scoped to this request's model queue: other
            # models' (and on a shared pipeline, other sessions')
            # queues keep coalescing
            self._pipeline.flush(self._model)
            if self._result is None and self._error is None:
                self._pipeline.flush()    # defensive full barrier
        if self._error is not None:
            raise self._error
        if self._result is None:      # pragma: no cover - defensive
            raise RuntimeError("pipeline flush did not resolve future")
        return self._result


@dataclasses.dataclass
class PipelineConfig:
    max_batch: int = 512          # flush-on-size threshold / dispatch size
    dedup: bool = True
    cache_size: int = 65536       # memoized results (LRU eviction)
    # seconds a memoized result stays servable; None = no expiry.  The
    # serving runtime sets this so cross-query answers age out instead
    # of serving stale results forever.
    cache_ttl_s: Optional[float] = None
    # transient-fault policy: a failed dispatch (EngineFailure or
    # SchedulerError, e.g. every replica faulted) is re-dispatched up to
    # max_retries more times with exponential backoff; after that the
    # affected futures resolve with RequestFailed (clean error, no hang).
    # NB: the backoff sleep runs inside the single-dispatcher lock, so
    # it pauses every session — keep base * 2^max_retries small
    max_retries: int = 2
    retry_backoff_s: float = 0.002       # base backoff (doubles per retry)
    retry_backoff_cap_s: float = 0.25    # backoff ceiling


@dataclasses.dataclass
class PipelineStats:
    submitted: int = 0            # requests entering the pipeline
    dispatched: int = 0           # requests actually sent to the scheduler
    batches: int = 0              # scheduler submits issued
    dedup_hits: int = 0           # total coalesced duplicates (both kinds)
    inflight_hits: int = 0        # attached to a queued identical request
    cache_hits: int = 0           # served from the memoized result cache
    cross_query_hits: int = 0     # cache/in-flight hits from another owner
    cache_expired: int = 0        # memoized results evicted past their TTL
    flushes_on_size: int = 0
    flushes_on_barrier: int = 0
    cancelled: int = 0            # queued requests cancelled pre-dispatch
    retries: int = 0              # batch re-dispatches after a fault
    failures: int = 0             # requests that exhausted their retries
    queue_wait_s: float = 0.0     # sum over dispatched reqs of queue time
    batch_size_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    # submissions per request kind (score/classify/complete): lets the
    # stats store / docs attribute dedup wins to operator families
    kind_hist: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def dedup_hit_rate(self) -> float:
        return self.dedup_hits / self.submitted if self.submitted else 0.0

    def snapshot(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["batch_size_hist"] = dict(self.batch_size_hist)
        return d

    def delta(self, before: Dict[str, Any]) -> Dict[str, Any]:
        """Per-query telemetry: stats accumulated since ``before``."""
        now = self.snapshot()
        out: Dict[str, Any] = {}
        for k, v in now.items():
            if isinstance(v, dict):
                prev = before.get(k, {})
                out[k] = {sz: n - prev.get(sz, 0) for sz, n in v.items()
                          if n - prev.get(sz, 0)}
            else:
                out[k] = v - before.get(k, 0)
        sub = out.get("submitted", 0)
        out["dedup_hit_rate"] = out["dedup_hits"] / sub if sub else 0.0
        return out


class _QueueItem:
    __slots__ = ("request", "futures", "enqueued_at", "owner", "owners",
                 "trace_t0")

    def __init__(self, request: Request, future: ResultFuture, t: float,
                 owner: Optional[str] = None):
        self.request = request
        self.futures = [future]
        self.enqueued_at = t
        self.owner = owner            # billed at dispatch (primary submitter)
        self.owners = {owner}         # every owner with an attached future
        # submit timestamp on the *tracer's* clock (None untraced) — the
        # dispatch span's queue_wait_s must stay deterministic under an
        # injected clock, so it never reads perf_counter
        self.trace_t0 = None


class _CacheEntry:
    __slots__ = ("result", "expires_at", "owner")

    def __init__(self, result: Result, expires_at: Optional[float],
                 owner: Optional[str]):
        self.result = result
        self.expires_at = expires_at
        self.owner = owner


_ALL_OWNERS = object()                # sentinel: flush regardless of owner


class RequestPipeline:
    """Coalescing, deduplicating, fault-retrying request queue in front
    of the Scheduler.  Safe for concurrent submitters (see module
    docstring for the locking model)."""

    def __init__(self, scheduler: Scheduler,
                 cfg: Optional[PipelineConfig] = None, *,
                 on_dispatch: Optional[Callable[[List[Result]], None]] = None):
        self.scheduler = scheduler
        self.cfg = cfg or PipelineConfig()
        self.on_dispatch = on_dispatch
        self.stats = PipelineStats()
        # optional `MetricsRegistry` (set by the serving runtime):
        # dispatched batch sizes are observed there
        self.registry = None
        self._lock = threading.RLock()
        self._queues: Dict[str, List[_QueueItem]] = {}
        self._inflight: Dict[Tuple, _QueueItem] = {}
        # LRU: dict order is recency — hits move entries to the end,
        # eviction pops from the front
        self._cache: Dict[Tuple, _CacheEntry] = {}
        # per-owner dispatch meters (serving: one per session)
        self._meters: Dict[str, Callable[[List[Result]], None]] = {}

    # ------------------------------------------------------------------
    # owner metering (serving runtime)
    # ------------------------------------------------------------------

    def register_meter(self, owner: str,
                       fn: Callable[[List[Result]], None]) -> None:
        """Bill ``owner``'s dispatched requests through ``fn`` instead of
        the default ``on_dispatch`` hook (exactly one of the two sees
        each dispatched result — spend is conserved)."""
        with self._lock:
            self._meters[owner] = fn

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def submit(self, request: Request,
               owner: Optional[str] = None) -> ResultFuture:
        return self.submit_many([request], owner=owner)[0]

    def submit_many(self, requests: Sequence[Request], *,
                    owner: Optional[str] = None) -> List[ResultFuture]:
        with self._lock:
            return self._submit_many_locked(requests, owner)

    def _submit_many_locked(self, requests: Sequence[Request],
                            owner: Optional[str]) -> List[ResultFuture]:
        now = time.perf_counter()
        tr = active_tracer()
        futures: List[ResultFuture] = []
        touched: List[str] = []
        # dedup hits are the hottest pipeline path (thousands per query
        # on a warm cache): trace them as ONE aggregated event per
        # submit call, never one event per request
        hit_cache = hit_inflight = 0
        for r in requests:
            self.stats.submitted += 1
            self.stats.kind_hist[r.kind] = \
                self.stats.kind_hist.get(r.kind, 0) + 1
            key = request_fingerprint(r) if self.cfg.dedup else None
            if key is not None:
                cached = self._cache_get(key, owner)
                if cached is not None:
                    self.stats.dedup_hits += 1
                    self.stats.cache_hits += 1
                    hit_cache += 1
                    futures.append(ResultFuture.resolved(cached))
                    continue
                pending = self._inflight.get(key)
                if pending is not None:
                    f = ResultFuture(self, r.model)
                    pending.futures.append(f)
                    pending.owners.add(owner)
                    self.stats.dedup_hits += 1
                    self.stats.inflight_hits += 1
                    if owner != pending.owner:
                        self.stats.cross_query_hits += 1
                    hit_inflight += 1
                    futures.append(f)
                    continue
            f = ResultFuture(self, r.model)
            item = _QueueItem(r, f, now, owner)
            if tr.enabled:
                item.trace_t0 = tr.now()
            self._queues.setdefault(r.model, []).append(item)
            if key is not None:
                self._inflight[key] = item
            futures.append(f)
            touched.append(r.model)
        if tr.enabled and (hit_cache or hit_inflight):
            tr.event("pipeline.dedup_hit", cache=hit_cache,
                     inflight=hit_inflight)
        for model in dict.fromkeys(touched):
            if len(self._queues.get(model, ())) >= self.cfg.max_batch:
                self.stats.flushes_on_size += 1
                self._flush_model(model)
        return futures

    # ------------------------------------------------------------------
    # flushing / dispatch
    # ------------------------------------------------------------------

    @property
    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def flush(self, model: Optional[str] = None,
              owner: Any = _ALL_OWNERS) -> None:
        """Barrier: dispatch every queued request, or one model's queue,
        or — with ``owner=`` — only the items a given owner submitted
        (the serving engine's per-session barrier: other sessions' work
        stays queued and keeps coalescing)."""
        with self._lock:
            models = [model] if model is not None else list(self._queues)
            flushed_any = False
            for m in models:
                if not self._queues.get(m):
                    continue
                if owner is _ALL_OWNERS:
                    flushed_any = True
                    self._flush_model(m)
                else:
                    mine = [it for it in self._queues[m]
                            if it.owner == owner]
                    if not mine:
                        continue
                    rest = [it for it in self._queues[m]
                            if it.owner != owner]
                    if rest:
                        self._queues[m] = rest
                    else:
                        del self._queues[m]
                    flushed_any = True
                    self._dispatch_chunked(mine)
            if flushed_any:
                self.stats.flushes_on_barrier += 1

    def cancel(self, futures: Sequence[ResultFuture], *,
               owner: Optional[str] = None) -> int:
        """Cancel still-queued requests — the LIMIT-aware early-termination
        hook: a streaming consumer that has its ``n`` rows withdraws the
        speculative partitions it no longer needs *before* they are
        dispatched, so they never reach an engine or the credit meter.

        A queued request is cancelled only when **every** future attached
        to it (the original plus any dedup attachments) is in ``futures``
        — work another call site still awaits is left untouched.  Requests
        already dispatched (or resolved) cannot be cancelled.  Returns the
        number of requests removed from the queues.

        On a shared pipeline pass ``owner=``: a surviving dedup-shared
        item the canceller no longer awaits has the cancelled futures
        detached and, if the canceller held the billing tag, the tag
        moves to a surviving owner — a session is never billed for a
        dispatch that only served other sessions.
        """
        with self._lock:
            want = {id(f) for f in futures}
            cancelled = self._cancel_items_locked(
                lambda item: item.futures and all(
                    id(f) in want for f in item.futures))
            if owner is not None:
                for q in self._queues.values():
                    for item in q:
                        mine = [f for f in item.futures if id(f) in want]
                        if not mine:
                            continue
                        for f in mine:
                            item.futures.remove(f)
                            f._cancelled = True
                        others = [o for o in item.owners if o != owner]
                        if item.owner == owner and others:
                            item.owner = others[0]
            return cancelled

    def cancel_owner(self, owner: Optional[str]) -> int:
        """Cancel every still-queued request that belongs *only* to
        ``owner`` — the failed-query cleanup hook: a query that errors
        out must not leave work behind that a later barrier would
        dispatch (and bill) on its behalf.  Items another owner has
        dedup-attached to stay queued (that owner still awaits them),
        but the billing tag moves to a surviving owner so the eventual
        dispatch is never charged to the failed query."""
        with self._lock:
            cancelled = self._cancel_items_locked(
                lambda item: item.owners == {owner})
            # items other owners still await: drop the failed owner from
            # the ownership set entirely (primary or attached), so it is
            # never billed and a later cancel_owner of the last
            # surviving owner can actually cancel the item
            for q in self._queues.values():
                for item in q:
                    if owner in item.owners and item.owners != {owner}:
                        item.owners.discard(owner)
                        if item.owner == owner:
                            item.owner = next(iter(item.owners))
            return cancelled

    def _cancel_items_locked(self, should_cancel) -> int:
        cancelled = 0
        for model in list(self._queues):
            kept: List[_QueueItem] = []
            for item in self._queues[model]:
                if should_cancel(item):
                    cancelled += 1
                    for f in item.futures:
                        f._cancelled = True
                    if self.cfg.dedup:
                        self._inflight.pop(
                            request_fingerprint(item.request), None)
                else:
                    kept.append(item)
            if kept:
                self._queues[model] = kept
            else:
                del self._queues[model]
        self.stats.cancelled += cancelled
        return cancelled

    def _flush_model(self, model: str) -> None:
        queue = self._queues.pop(model, None)
        if queue:
            self._dispatch_chunked(queue)

    def _dispatch_chunked(self, items: List[_QueueItem]) -> None:
        """Dispatch a (single-model) run of queue items in chunks.

        Chunks never exceed the scheduler's ``atomic_batch`` for the
        model: an unsplit submit is all-or-nothing, so the retry loop in
        `_dispatch` can never re-execute (and re-bill at the backend) a
        partition that already succeeded — dispatch spend stays exactly
        once per request.

        An *unexpected* exception type (anything the retry loop does not
        recognise as transient) fails this chunk's and every remaining
        chunk's futures cleanly and drops their dedup fingerprints
        before propagating — the items are already popped from the
        queues, so leaving them half-tracked would hang their futures
        and poison later identical submissions.
        """
        size = max(self.cfg.max_batch, 1)
        if items:
            atomic = self.scheduler.atomic_batch(items[0].request.model)
            if atomic is not None:
                size = min(size, atomic)
        for lo in range(0, len(items), size):
            try:
                self._dispatch(items[lo:lo + size])
            except Exception as e:
                err = RequestFailed(f"dispatch aborted by unexpected "
                                    f"error: {e}")
                err.__cause__ = e
                for it in items[lo:]:
                    if self.cfg.dedup:
                        self._inflight.pop(
                            request_fingerprint(it.request), None)
                    for f in it.futures:
                        f._fail(err)
                self.stats.failures += len(items) - lo
                raise

    def _dispatch(self, items: List[_QueueItem]) -> None:
        if not items:
            return
        t0 = time.perf_counter()
        tr = active_tracer()
        requests = [it.request for it in items]
        if self.registry is not None:
            self.registry.histogram(
                "aisql_pipeline_batch_size").observe(float(len(items)))
        with tr.span("pipeline.dispatch", kind="pipeline.dispatch",
                     model=requests[0].model,
                     requests=len(items)) as dsp:
            if tr.enabled and len(items) > 1:
                tr.event("pipeline.coalesce", requests=len(items))
            results: Optional[List[Result]] = None
            last_exc: Optional[Exception] = None
            for attempt in range(self.cfg.max_retries + 1):
                if attempt:
                    # transient fault: back off, then re-dispatch the
                    # same batch (the scheduler re-picks replicas
                    # underneath)
                    self.stats.retries += 1
                    tr.event("pipeline.retry", attempt=attempt)
                    time.sleep(min(
                        self.cfg.retry_backoff_s * (2 ** (attempt - 1)),
                        self.cfg.retry_backoff_cap_s))
                try:
                    results = self.scheduler.submit(requests)
                    break
                except (EngineFailure, SchedulerError) as e:
                    last_exc = e
            if tr.enabled and results is not None:
                waits = [it.trace_t0 for it in items
                         if it.trace_t0 is not None]
                dsp.set(credits=float(sum(r.credits for r in results)),
                        tokens_in=int(sum(r.tokens_in for r in results)),
                        tokens_out=int(sum(r.tokens_out
                                           for r in results)),
                        queue_wait_s=(tr.now() - min(waits)
                                      if waits else 0.0),
                        outcome="ok")
            elif tr.enabled:
                dsp.set(outcome="failed")
        if results is None:
            # retries exhausted: resolve every attached future with a
            # clean error — never a silent drop, never a hang.  Nothing
            # was billed (metering happens only on success below).
            self.stats.failures += len(items)
            for it in items:
                if self.cfg.dedup:
                    self._inflight.pop(request_fingerprint(it.request), None)
                err = RequestFailed(
                    f"request permanently failed after "
                    f"{self.cfg.max_retries} pipeline retries: {last_exc}")
                err.__cause__ = last_exc
                for f in it.futures:
                    f._fail(err)
            return
        self.stats.batches += 1
        self.stats.dispatched += len(items)
        self.stats.batch_size_hist[len(items)] = \
            self.stats.batch_size_hist.get(len(items), 0) + 1
        self._bill(items, results)
        for it, res in zip(items, results):
            self.stats.queue_wait_s += t0 - it.enqueued_at
            key = request_fingerprint(it.request) if self.cfg.dedup else None
            if key is not None:
                self._inflight.pop(key, None)
                self._remember(key, res, it.owner)
            for f in it.futures:
                f._resolve(res)

    def _bill(self, items: List[_QueueItem], results: List[Result]) -> None:
        """Route each dispatched result to its owner's registered meter;
        everything else goes to the default ``on_dispatch`` hook.  Each
        result is billed exactly once."""
        default_bucket: List[Result] = []
        owned: Dict[str, List[Result]] = {}
        for it, res in zip(items, results):
            meter = self._meters.get(it.owner) if it.owner is not None \
                else None
            if meter is not None:
                owned.setdefault(it.owner, []).append(res)
            else:
                default_bucket.append(res)
        for owner, rs in owned.items():
            self._meters[owner](rs)
        if default_bucket and self.on_dispatch is not None:
            self.on_dispatch(default_bucket)

    # ------------------------------------------------------------------
    # memoized result cache (LRU + optional TTL)
    # ------------------------------------------------------------------

    def _cache_get(self, key: Tuple,
                   owner: Optional[str]) -> Optional[Result]:
        entry = self._cache.get(key)
        if entry is None:
            return None
        if (entry.expires_at is not None
                and time.monotonic() >= entry.expires_at):
            del self._cache[key]
            self.stats.cache_expired += 1
            return None
        # LRU: a hit moves the entry to the recent end so hot keys
        # survive eviction pressure
        self._cache.pop(key)
        self._cache[key] = entry
        if entry.owner != owner:
            self.stats.cross_query_hits += 1
        return entry.result

    def _remember(self, key: Tuple, result: Result,
                  owner: Optional[str]) -> None:
        cap = self.cfg.cache_size
        if cap <= 0:
            return
        self._cache.pop(key, None)
        while len(self._cache) >= cap:
            # evict the least-recently-used entry (front of the dict)
            self._cache.pop(next(iter(self._cache)))
        ttl = self.cfg.cache_ttl_s
        expires = time.monotonic() + ttl if ttl is not None else None
        self._cache[key] = _CacheEntry(result, expires, owner)

    def stats_snapshot(self) -> Dict[str, Any]:
        """Point-in-time copy of `PipelineStats` taken under the
        pipeline lock, so the counters are mutually consistent (no
        dispatch can land between reading ``submitted`` and
        ``dispatched``)."""
        return locked_snapshot(self._lock, self.stats.snapshot)

    def stats_delta(self, before: Dict[str, Any]) -> Dict[str, Any]:
        """`PipelineStats.delta` under the pipeline lock (atomic with
        respect to a concurrent dispatch)."""
        return locked_snapshot(self._lock,
                               lambda: self.stats.delta(before))

    def cache_keys(self):
        with self._lock:
            return list(self._cache)

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()
