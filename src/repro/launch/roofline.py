"""Roofline analysis from the compiled dry-run artifact (no hardware).

Three terms per (arch × shape × mesh), all in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = Σ_ops collective_bytes_per_device(op) / link_bw

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes (XLA reports the
per-partition module after SPMD partitioning).  Collective bytes are NOT in
cost_analysis — we parse the post-optimization HLO text and sum the wire
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, applying ring-algorithm factors over the actual
replica-group size parsed per op.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# "bf16[2048,4096]{1,0}" -> bytes
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COLL_RE = re.compile(
    r"^\s*(?:%)?(\S+)\s*=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
    re.MULTILINE)

_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int

    @property
    def wire_bytes(self) -> float:
        """Ring-algorithm bytes over the slowest link, per device."""
        n = max(self.group_size, 1)
        if n == 1:
            return 0.0
        b = self.result_bytes
        if self.kind == "all-reduce":
            # reduce-scatter + all-gather: 2(n-1)/n × full buffer
            return 2.0 * (n - 1) / n * b
        if self.kind == "all-gather":
            # result is the gathered buffer; each device receives (n-1)/n
            return (n - 1) / n * b
        if self.kind == "reduce-scatter":
            # result is the scattered shard; wire = (n-1) shards
            return (n - 1) * b
        if self.kind == "all-to-all":
            return (n - 1) / n * b
        if self.kind == "collective-permute":
            return float(b)
        return float(b)


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    out: List[CollectiveOp] = []
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(2), m.group(3)
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start():line_end if line_end > 0 else None]
        gsize = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            gsize = len([x for x in gm.group(1).split(",") if x.strip()])
        else:
            gm2 = _GROUPS_ITOTA_RE.search(line)
            if gm2:
                gsize = int(gm2.group(2))
        out.append(CollectiveOp(kind, _shape_bytes(shape_str), gsize))
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # per device
    hlo_bytes: float             # per device
    collective_bytes: float      # per device (wire)
    model_flops: float           # analytic 6ND / 2ND (global)
    collectives: Dict[str, int] = dataclasses.field(default_factory=dict)
    memory_per_device: Optional[Dict[str, float]] = None
    detail: Optional[Dict[str, Any]] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): how much compiled compute is
        'useful' — catches remat/redundancy waste."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilisation *upper bound* at the roofline: useful
        FLOPs / (chips × peak × bound-time)."""
        denom = self.chips * PEAK_FLOPS * self.t_bound
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "collective_bytes_per_dev": self.collective_bytes,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flop_ratio": self.useful_flop_ratio,
            "mfu_bound": self.mfu_bound,
            "collectives": self.collectives,
            "memory_per_device": self.memory_per_device,
            "detail": self.detail,
        }


def analyze(cell, lowered=None, compiled=None) -> Roofline:
    """Run the lower/compile (if not supplied) and extract the terms.

    FLOPs/bytes/collectives come from the trip-count-aware HLO walk
    (``hlo_cost``) because XLA's ``cost_analysis()`` counts while-loop
    bodies once (verified empirically) — scan-over-layers models would be
    undercounted by ~num_layers.  The raw cost_analysis numbers are kept
    in the record for cross-reference.
    """
    from repro.launch.hlo_cost import analyze_hlo
    if lowered is None:
        lowered = cell.lower()
    if compiled is None:
        compiled = lowered.compile()
    chips = cell.mesh.size
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older jax returns [dict]
        cost = cost[0]
    hlo = compiled.as_text()
    hc = analyze_hlo(hlo)
    flops = hc.flops
    byts = hc.bytes
    wire = hc.collective_bytes
    counts = dict(hc.collective_counts)
    by_path = {
        "collective_by_path": dict(sorted(hc.collective_by_path.items(),
                                          key=lambda kv: -kv[1])[:8]),
        "flops_by_path": dict(sorted(hc.flops_by_path.items(),
                                     key=lambda kv: -kv[1])[:8]),
        "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
        "xla_cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
    }
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": float(
                getattr(ma, "peak_memory_in_bytes",
                        getattr(ma, "temp_size_in_bytes", 0))),
        }
    except Exception:
        pass
    mesh_name = "x".join(str(s) for s in cell.mesh.devices.shape)
    return Roofline(
        arch=cell.arch, shape=cell.shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, collective_bytes=wire,
        model_flops=cell.model_flops, collectives=counts,
        memory_per_device=mem, detail=by_path)


def analyze_jitted(jitted, args, *, arch: str, shape: str,
                   model_flops: float = 0.0, chips: int = 1) -> Roofline:
    """Roofline terms for one jitted step function (single device).

    ``args`` are ShapeDtypeStructs (or arrays) matching the call signature
    — the function is AOT lowered/compiled and its post-optimization HLO
    walked with trip counts (``hlo_cost``), exactly as :func:`analyze`
    does for dry-run cells.  Used by the serving backend to report
    utilization per decode/prefill step mix.
    """
    from repro.launch.hlo_cost import analyze_hlo
    compiled = jitted.lower(*args).compile()
    hc = analyze_hlo(compiled.as_text())
    return Roofline(
        arch=arch, shape=shape, mesh="1", chips=chips,
        hlo_flops=hc.flops, hlo_bytes=hc.bytes,
        collective_bytes=hc.collective_bytes, model_flops=model_flops,
        collectives=dict(hc.collective_counts))


def fmt_row(r: Roofline) -> str:
    return (f"{r.arch:22s} {r.shape:12s} {r.mesh:9s} "
            f"C={r.t_compute*1e3:9.2f}ms M={r.t_memory*1e3:9.2f}ms "
            f"X={r.t_collective*1e3:9.2f}ms -> {r.bottleneck:10s} "
            f"useful={r.useful_flop_ratio:6.2%} mfu_bound={r.mfu_bound:6.2%}")
