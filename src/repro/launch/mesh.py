"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialisation.

Topology (TPU v5e pods):
  single-pod: (data=16, model=16)            = 256 chips
  multi-pod:  (pod=2, data=16, model=16)     = 512 chips

The `pod` axis is pure data parallelism: gradients cross pods once per
step (training); serving shards request batches across pods with no
cross-pod collectives.  Scaling to N pods adds no new collective patterns.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Tiny mesh over the actually-available devices (CI-scale tests)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except `model`)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def tp_axis(mesh) -> str:
    return "model"
