"""Trip-count-aware cost model over compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` over 64 layers contributes its body a single time, so flops /
bytes / collectives are undercounted by the trip count (verified
empirically on this backend: scan(8×matmul) reports 1×matmul flops).

This module re-derives the three roofline inputs by walking the HLO call
graph with multiplicities:

  * computations reached through ``while`` bodies inherit
    ``known_trip_count`` from the op's backend_config (jax scans always
    carry it);
  * ``fusion``/``call``/``conditional`` propagate the caller multiplicity;
  * per-op costs: ``dot`` = 2·prod(result)·contraction; elementwise ~1
    flop/elem (transcendentals 8); ``reduce`` counts its operand once;
  * traffic bytes are counted at fusion/dot/copy/dus/… boundaries —
    post-fusion, these are the buffers that actually move through HBM;
  * collectives get ring-algorithm wire bytes × multiplicity, tagged with
    the jax op_name path so hot spots are attributable (attn vs mlp vs
    optimizer).

Everything is derived from ``compiled.as_text()`` — the artifact the
dry-run already produces.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_TRANSCENDENTAL = {"tanh", "exp", "exponential", "log", "rsqrt", "sqrt",
                   "power", "logistic", "sine", "cosine", "atan2",
                   "exponential-minus-one", "log-plus-one", "erf", "cbrt"}
_ELEMENTWISE = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
                "compare", "select", "and", "or", "xor", "not", "negate",
                "abs", "sign", "floor", "ceil", "round-nearest-afz",
                "round-nearest-even", "clamp", "convert", "shift-left",
                "shift-right-logical", "shift-right-arithmetic", "remainder",
                "is-finite", "popcnt", "clz", "stochastic-convert"}
_TRAFFIC_OPS = {"fusion", "dot", "copy", "dynamic-update-slice",
                "dynamic-slice", "gather", "scatter", "reduce", "transpose",
                "convert", "broadcast", "concatenate", "slice", "pad",
                "reverse", "select-and-scatter", "custom-call", "reshape",
                "reduce-window", "sort", "iota", "rng", "cholesky",
                "triangular-solve", "convolution", "copy-start"}
_SKIP_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "copy-done",
             "get-dimension-size", "opt-barrier"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}


def shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) across every array in a (tuple) shape str."""
    elems = 0
    byts = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    shape: str          # result shape string
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op] = dataclasses.field(default_factory=list)
    shapes: Dict[str, str] = dataclasses.field(default_factory=dict)


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^()]*\)|\S+?))\s+"    # result shape (tuples have no inner parens)
    r"([\w\-]+)\(")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?"?(\d+)"?')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _operand_str(op: "Op") -> str:
    """Text inside the operand parens of the op invocation.

    Handles both untyped (`dot(%a, %b)`) and typed
    (`dot(f32[64,128]{1,0} %a, ...)`) operand prints, and tuple-typed
    operands whose *types* nest parens (`gte((s32[], f32[2]) %t)`).
    Anchored after the `=` so a `%dot.3 = ... dot(...)` instruction name
    doesn't shadow the opcode.
    """
    eq = op.line.find("=")
    i = op.line.find(op.kind + "(", eq + 1)
    if i < 0:
        return ""
    start = i + len(op.kind) + 1
    depth = 1
    j = start
    line = op.line
    while j < len(line) and depth:
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
        j += 1
    return line[start:j - 1]


def _split_top(s: str):
    """Split on commas not nested inside (), [], or {}."""
    parts, cur, depth = [], [], 0
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _operand_names(op: "Op"):
    """Operand instruction names, in order (typed or untyped prints)."""
    names = []
    for part in _split_top(_operand_str(op)):
        part = part.strip()
        if not part:
            continue
        names.append(part.split()[-1].lstrip("%"))
    return names


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if line and not line[0].isspace() and "->" in line \
                and line.rstrip().endswith("{"):
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, shape, kind = m.group(1), m.group(2), m.group(3)
            cur.ops.append(Op(name, kind, shape, line))
            cur.shapes[name] = shape
    return comps, entry


def _multiplicities(comps: Dict[str, Computation], entry: str
                    ) -> Tuple[Dict[str, float], set]:
    mult: Dict[str, float] = defaultdict(float)
    fusion_called: set = set()

    def visit(comp_name: str, m: float):
        comp = comps.get(comp_name)
        if comp is None:
            return
        mult[comp_name] += m
        for op in comp.ops:
            if op.kind == "while":
                trips = 1.0
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trips = float(tm.group(1))
                bm = _BODY_RE.search(op.line)
                cm = _COND_RE.search(op.line)
                if bm:
                    visit(bm.group(1), m * trips)
                if cm:
                    visit(cm.group(1), m * (trips + 1))
            elif op.kind in ("fusion", "call", "async-start"):
                cm = _CALLS_RE.search(op.line) or _TOAPPLY_RE.search(op.line)
                if cm:
                    if op.kind == "fusion":
                        fusion_called.add(cm.group(1))
                    visit(cm.group(1), m)
            elif op.kind == "conditional":
                bm = _BRANCHES_RE.search(op.line)
                if bm:
                    for b in bm.group(1).split(","):
                        visit(b.strip().lstrip("%"), m)
            # reduce/map/sort to_apply bodies: per-element scalar ops —
            # accounted via the reduce op itself, not traversed.
    visit(entry, 1.0)
    return mult, fusion_called


def _dot_flops(op: Op, comp: Computation) -> float:
    relems, _ = shape_elems_bytes(op.shape)
    contract = 1
    cm = _CONTRACT_RE.search(op.line)
    names = _operand_names(op)
    if cm and names:
        lhs_shape = comp.shapes.get(names[0], "")
        # typed operand prints carry the shape inline: fall back to it
        if not lhs_shape:
            parts = _split_top(_operand_str(op))
            if parts:
                lhs_shape = parts[0]
        sm = _SHAPE_RE.search(lhs_shape)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for ci in cm.group(1).split(","):
                if ci.strip():
                    i = int(ci)
                    if i < len(dims):
                        contract *= dims[i]
    return 2.0 * relems * contract


def _op_operand_bytes(op: Op, comp: Computation) -> int:
    total = 0
    for nm in _operand_names(op):
        sh = comp.shapes.get(nm)
        if sh:
            total += shape_elems_bytes(sh)[1]
    return total


def _first_operand(op: Op) -> Optional[str]:
    names = _operand_names(op)
    return names[0] if names else None


def _unwrap(comp: Computation, op: Op, kinds=("convert", "bitcast", "copy")
            ) -> Op:
    """Follow single-operand wrapper ops (the CPU backend legalizes bf16
    DUS as convert→DUS→convert; TPU updates in place)."""
    by_name = {o.name: o for o in comp.ops}
    seen = 0
    while op.kind in kinds and seen < 8:
        nm = _first_operand(op)
        if nm is None or nm not in by_name:
            break
        op = by_name[nm]
        seen += 1
    return op


def _dus_update_bytes(comp: Computation) -> Optional[float]:
    """If the computation's ROOT is (a wrapper around) a
    dynamic-update-slice (or a tuple of them), return the bytes of the
    update operands — the in-place pattern XLA buffer-assigns without
    copying the big buffer."""
    roots = [o for o in comp.ops if o.line.lstrip().startswith("ROOT")]
    if not roots:
        return None
    root = _unwrap(comp, roots[0])
    dus_ops = []
    if root.kind == "dynamic-update-slice":
        dus_ops = [root]
    elif root.kind == "tuple":
        names = set(_operand_names(root))
        dus_ops = [o for o in comp.ops
                   if o.name in names and o.kind == "dynamic-update-slice"]
        if not dus_ops:
            return None
    else:
        return None
    by_name = {o.name: o for o in comp.ops}
    total = 0.0
    for o in dus_ops:
        names = _operand_names(o)
        if len(names) < 2:
            return None
        upd_op = by_name.get(names[1])
        upd = _unwrap(comp, upd_op).shape if upd_op is not None \
            else comp.shapes.get(names[1])
        if upd is None:
            return None
        total += shape_elems_bytes(upd)[1]
    return total


def _param_slice_traffic(callee: Computation) -> Dict[int, float]:
    """Per-parameter-index traffic override for fused slicing reads.

    A fusion operand that is only consumed by dynamic-slice/gather inside
    the fused computation reads just the slice, not the whole buffer
    (the loop-body pattern: read layer i of a stacked [L, ...] array).
    Returns {param_index: effective_bytes}.
    """
    out: Dict[int, float] = {}
    params = {}
    for o in callee.ops:
        if o.kind == "parameter":
            pm = re.search(r"parameter\((\d+)\)", o.line)
            if pm:
                params[o.name] = int(pm.group(1))
    for pname, pidx in params.items():
        pat = re.compile(r"%" + re.escape(pname) + r"\b")
        users = [o for o in callee.ops
                 if o.name != pname and pat.search(o.line)]
        if users and all(u.kind in ("dynamic-slice", "slice", "gather")
                         for u in users):
            out[pidx] = float(sum(shape_elems_bytes(u.shape)[1]
                                  for u in users))
    return out


def _fusion_traffic(op: Op, comp: Computation, callee: Computation,
                    rbytes: int) -> float:
    """Traffic of one fusion execution: result write + operand reads, with
    the in-place-DUS root and fused-slice-read patterns accounted."""
    upd = _dus_update_bytes(callee)
    slice_reads = _param_slice_traffic(callee)
    # aliased operand index for a DUS root (operand 0 of the root DUS, when
    # it is a plain parameter)
    aliased_idx = None
    if upd is not None:
        roots = [o for o in callee.ops if o.line.lstrip().startswith("ROOT")]
        dus = _unwrap(callee, roots[0]) if roots else None
        if dus is not None and dus.kind == "dynamic-update-slice":
            first = _first_operand(dus)
            by_name = {o.name: o for o in callee.ops}
            o = by_name.get(first)
            if o is not None:
                o = _unwrap(callee, o)
                if o.kind == "parameter":
                    pm = re.search(r"parameter\((\d+)\)", o.line)
                    if pm:
                        aliased_idx = int(pm.group(1))
    total = 2.0 * upd if upd is not None else float(rbytes)
    for i, nm in enumerate(_operand_names(op)):
        if i == aliased_idx:
            continue                      # in-place: no full read/write
        if i in slice_reads:
            total += 2.0 * slice_reads[i]
            continue
        sh = comp.shapes.get(nm)
        if sh:
            total += shape_elems_bytes(sh)[1]
    return total


def _traffic_bytes(op: Op, comp: Computation, rbytes: int,
                   comps: Optional[Dict[str, Computation]] = None) -> float:
    """Realistic HBM traffic for one op execution.

    In-place-updating and slicing ops move only the slice, not the full
    buffer (XLA buffer-assigns DUS in place, including DUS-rooted loop
    fusions); reshapes are bitcasts.
    """
    kind = op.kind
    if kind == "reshape" or kind == "bitcast":
        return 0.0
    if kind == "dynamic-update-slice":
        names = _operand_names(op)
        if len(names) > 1:
            upd = comp.shapes.get(names[1])
            if upd:
                return 2.0 * shape_elems_bytes(upd)[1]
        return float(rbytes)
    if kind == "fusion" and comps is not None:
        cm = _CALLS_RE.search(op.line)
        if cm and cm.group(1) in comps:
            return _fusion_traffic(op, comp, comps[cm.group(1)], rbytes)
    if kind in ("dynamic-slice", "slice", "gather"):
        return 2.0 * rbytes          # read the slice + write it
    if kind in ("broadcast", "iota", "pad"):
        return float(rbytes)         # write-mostly
    return rbytes + _op_operand_bytes(op, comp)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0               # per device
    bytes: float = 0.0               # HBM traffic per device
    collective_bytes: float = 0.0    # wire bytes per device
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    collective_by_path: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    flops_by_path: Dict[str, float] = dataclasses.field(default_factory=dict)
    warnings: List[str] = dataclasses.field(default_factory=list)


def _wire_bytes(kind: str, result_bytes: int, operand_bytes: int,
                group: int) -> float:
    n = max(group, 1)
    if n == 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n * result_bytes
    if kind == "all-gather":
        return (n - 1) / n * result_bytes
    if kind == "reduce-scatter":
        return (n - 1) / n * operand_bytes
    if kind == "all-to-all":
        return (n - 1) / n * result_bytes
    if kind == "collective-permute":
        return float(result_bytes)
    return float(result_bytes)


def _path_tag(line: str) -> str:
    m = _OPNAME_RE.search(line)
    if not m:
        return "?"
    path = m.group(1)
    # compress: keep the distinctive trailing parts
    for tag in ("attn", "moe", "mlp", "rec", "rwkv", "embed", "lm_head",
                "logits", "adamw", "grad", "loss", "rglru", "wkv",
                "transpose(jvp", "norm"):
        if tag in path:
            return tag
    parts = path.split("/")
    return parts[-1][:40] if parts else "?"


def analyze_hlo(hlo: str) -> HloCost:
    comps, entry = parse_computations(hlo)
    mult, fusion_called = _multiplicities(comps, entry)
    out = HloCost()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        fused = cname in fusion_called
        for op in comp.ops:
            kind = op.kind
            base = kind.replace("-start", "")
            if base in _COLLECTIVES and not kind.endswith("-done"):
                relems, rbytes = shape_elems_bytes(op.shape)
                obytes = _op_operand_bytes(op, comp)
                g = 1
                gm = _GROUPS_RE.search(op.line)
                if gm:
                    g = len([x for x in gm.group(1).split(",") if x.strip()])
                else:
                    gm2 = _GROUPS_IOTA_RE.search(op.line)
                    if gm2:
                        g = int(gm2.group(2))
                wb = _wire_bytes(base, rbytes, obytes, g) * m
                out.collective_bytes += wb
                out.collective_counts[base] = \
                    out.collective_counts.get(base, 0) + int(m)
                tag = _path_tag(op.line)
                out.collective_by_path[tag] = \
                    out.collective_by_path.get(tag, 0.0) + wb
                continue
            if kind in _SKIP_OPS or kind == "while" or kind == "conditional":
                continue
            # ---- flops ----
            relems, rbytes = shape_elems_bytes(op.shape)
            if kind == "dot":
                f = _dot_flops(op, comp) * m
                out.flops += f
                tag = _path_tag(op.line)
                out.flops_by_path[tag] = out.flops_by_path.get(tag, 0.0) + f
            elif kind in _TRANSCENDENTAL:
                out.flops += 8.0 * relems * m
            elif kind in _ELEMENTWISE or kind in ("reduce", "map"):
                out.flops += 1.0 * relems * m
            # ---- bytes (traffic at non-fused op boundaries) ----
            if not fused and kind in _TRAFFIC_OPS:
                out.bytes += _traffic_bytes(op, comp, rbytes, comps) * m
    return out
