"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-8b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container the driver runs reduced (smoke) configs on the host
mesh; on a TPU fleet the same code takes ``--production-mesh`` and the
full configs (the dry-run proves those lower+compile).  Features: sharded
state, deterministic resume, checkpoint/restart, straggler telemetry.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import base as cfgs
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model_zoo, shardctx
from repro.train.checkpoint import CheckpointManager
from repro.train.data import TokenPipeline
from repro.train.loop import LoopConfig, Trainer
from repro.train.optim import AdamWConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b",
                    choices=list(cfgs.ARCH_IDS) + list(cfgs.EXTRA_IDS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    mesh = make_host_mesh(model=args.model_parallel)
    model = model_zoo.build(args.arch, smoke=True)
    pipe = TokenPipeline(model.cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir)
    trainer = Trainer(
        model, pipe, ckpt,
        loop=LoopConfig(total_steps=args.steps,
                        checkpoint_every=args.ckpt_every),
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps),
        seed=args.seed)
    shardctx.enable(mesh)
    try:
        with mesh:
            out = trainer.run()
    finally:
        shardctx.disable()
    hist = out["history"]
    print(f"arch={args.arch} steps={len(hist)} "
          f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"resumed_from={out['resumed_from']} "
          f"stragglers={out['straggler_steps']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
