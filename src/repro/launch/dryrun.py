import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# The two lines above MUST run before any other import (jax locks the device
# count at first init) — do not move them.

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and record memory/cost/roofline evidence.
(No ``from __future__`` here: the XLA_FLAGS lines must stay first.)

Usage:
  python -m repro.launch.dryrun                      # all cells, both meshes
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --multi-pod-only --json out.json

Success criterion (deliverable e): .lower().compile() succeeds for the
16×16 single-pod mesh AND the 2×16×16 multi-pod mesh for every assigned
cell; the printed memory_analysis proves the state fits per device and
cost_analysis feeds §Roofline.
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, List, Optional

import jax

from repro.configs import base as cfgs

# Per-arch train-cell settings from the S-Perf hillclimb: sequence
# parallelism wins for MoE/VL (and is required for their HBM fit);
# dense/recurrent archs fit better via grad accumulation alone (SP
# regressed their collective term, catastrophically so for RWKV's
# time-scan).  Serve cells are tuned inside build_cell.
TRAIN_POLICY = {
    "phi3.5-moe-42b-a6.6b": {"sp": True},
    "qwen2-moe-a2.7b": {"sp": True},
    "qwen2-vl-7b": {"sp": True},
    "minitron-8b": {"grad_accum": 8},
    "qwen3-32b": {"grad_accum": 16},
    "command-r-35b": {"grad_accum": 16},
    "stablelm-12b": {"grad_accum": 8},
    "whisper-base": {"grad_accum": 8},
    "rwkv6-1.6b": {"grad_accum": 4},
    "recurrentgemma-9b": {"grad_accum": 4},
}
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             grad_accum: Optional[int] = None, remat: bool = True,
             sp: bool = False, verbose: bool = True) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    cell = build_cell(arch, shape_name, mesh, grad_accum=grad_accum,
                      remat=remat, sp=sp)
    with mesh:
        lowered = cell.lower()
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    roof = rl.analyze(cell, lowered=lowered, compiled=compiled)
    dt = time.perf_counter() - t0
    rec = roof.to_dict()
    rec.update({"ok": True, "compile_s": dt, "multi_pod": multi_pod})
    if verbose:
        print(f"[OK] {arch} × {shape_name} × {rec['mesh']} "
              f"({dt:.1f}s compile)")
        if mem is not None:
            print(f"     memory/device: args={_gb(mem.argument_size_in_bytes)} "
                  f"out={_gb(mem.output_size_in_bytes)} "
                  f"temp={_gb(mem.temp_size_in_bytes)}")
        print("     " + rl.fmt_row(roof))
    return rec


def _gb(b) -> str:
    return f"{b/2**30:.2f}GiB"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel layer carry for train cells")
    ap.add_argument("--optimized", action="store_true",
                    help="per-arch tuned settings from the perf pass")
    ap.add_argument("--json", default=None, help="append records to file")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(cfgs.ARCH_IDS)
    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    records: List[Dict[str, Any]] = []
    failures: List[str] = []
    for arch in archs:
        shapes = ([cfgs.SHAPE_BY_NAME[args.shape]] if args.shape
                  else cfgs.cells(arch))
        for (s, reason) in cfgs.skipped_cells(arch):
            if args.shape and s.name != args.shape:
                continue
            records.append({"arch": arch, "shape": s.name, "ok": True,
                            "skipped": reason})
            print(f"[SKIP] {arch} × {s.name}: {reason}")
        for shape in shapes:
            for mp in meshes:
                try:
                    pol = (TRAIN_POLICY.get(arch, {})
                           if args.optimized and shape.kind == "train"
                           else {})
                    records.append(run_cell(
                        arch, shape.name, multi_pod=mp,
                        grad_accum=args.grad_accum or pol.get("grad_accum"),
                        remat=not args.no_remat,
                        sp=pol.get("sp", args.sp and shape.kind == "train")))
                except Exception as e:  # noqa: BLE001 — report, keep going
                    failures.append(f"{arch}×{shape.name}×mp={mp}: {e}")
                    traceback.print_exc()
                    records.append({"arch": arch, "shape": shape.name,
                                    "multi_pod": mp, "ok": False,
                                    "error": str(e)})
    if args.json:
        existing = []
        try:
            with open(args.json) as f:
                existing = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            pass
        with open(args.json, "w") as f:
            json.dump(existing + records, f, indent=1)
    print(f"\n{sum(1 for r in records if r.get('ok'))}/{len(records)} cells OK")
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  " + f)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
