"""Cell builders: one lowerable jit function per (arch × shape) cell.

``build_cell(arch, shape_name, mesh)`` returns a :class:`Cell` carrying the
jit-wrapped function, abstract input avals (ShapeDtypeStructs — nothing is
allocated), and the in/out shardings.  ``cell.lower()`` is what the
multi-pod dry-run and the roofline analysis consume.

Cell kinds:
  train   -> train_step(state, batch)            (loss/grad/adamw)
  prefill -> prefill_step(params, batch)         (writes the KV cache)
  decode  -> serve_step(params, cache, batch)    (one token vs. seq_len cache)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import base as cfgs
from repro.models import model_zoo
from repro.launch import sharding as shd
from repro.train.optim import AdamWConfig
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class Cell:
    arch: str
    shape: cfgs.ShapeSpec
    mesh: Any
    fn: Callable
    in_avals: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    model_flops: float
    donate: Tuple[int, ...] = ()

    sp: bool = False           # sequence parallelism on the layer carry

    def jitted(self):
        return jax.jit(self.fn,
                       in_shardings=shd.named(self.mesh, self.in_shardings),
                       out_shardings=(None if self.out_shardings is None else
                                      shd.named(self.mesh, self.out_shardings)),
                       donate_argnums=self.donate)

    def lower(self):
        from repro.models import shardctx
        shardctx.enable(self.mesh, sp=self.sp)
        try:
            with self.mesh:
                return self.jitted().lower(*self.in_avals)
        finally:
            shardctx.disable()


def _abstract_state(model: model_zoo.Model) -> Dict[str, Any]:
    """TrainState avals via eval_shape (no allocation)."""
    def mk():
        params = model.init_params(jax.random.PRNGKey(0))
        opt = {
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               params),
            "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               params),
            "count": jnp.zeros((), jnp.int32),
        }
        return {"params": params, "opt_state": opt,
                "step": jnp.zeros((), jnp.int32)}
    return jax.eval_shape(mk)


def _abstract_params(model: model_zoo.Model):
    return jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))


def build_cell(arch: str, shape_name: str, mesh, *,
               smoke: bool = False,
               grad_accum: Optional[int] = None,
               remat: bool = True,
               sp: bool = False,
               extra_tags: Optional[Dict[str, Any]] = None) -> Cell:
    cfg = (cfgs.get_smoke_config(arch) if smoke else cfgs.get_config(arch))
    shape = cfgs.SHAPE_BY_NAME[shape_name]
    model = model_zoo.build(cfg)
    batch_avals = model_zoo.input_specs(cfg, shape)
    batch_spec = shd.batch_specs(mesh, batch_avals)
    mf = model_zoo.model_flops(cfg, shape)

    if shape.kind == "train":
        state_avals = _abstract_state(model)
        state_spec = shd.state_specs(mesh, state_avals)
        step_fn = make_train_step(model, AdamWConfig(),
                                  grad_accum=grad_accum, remat=remat)
        return Cell(arch, shape, mesh, step_fn, sp=sp,
                    in_avals=(state_avals, batch_avals),
                    in_shardings=(state_spec, batch_spec),
                    out_shardings=(state_spec, None),
                    model_flops=mf, donate=(0,))

    dp_size = 1
    for a in mesh.axis_names:
        if a != "model":
            dp_size *= mesh.shape[a]
    # serving weight layout: TP-only when DP actually has batch to split;
    # B=1 long-context cells keep FSDP weight sharding (pure weight
    # parallelism reads 1/16th the bytes per device)
    serve_fsdp = None if shape.global_batch % dp_size == 0 else "data"

    if shape.kind == "prefill":
        params_avals = _abstract_params(model)
        params_spec = shd.param_specs(mesh, params_avals, fsdp=serve_fsdp)
        cache_avals = model_zoo.cache_specs(cfg, shape)
        cache_spec = shd.cache_specs_tree(mesh, cache_avals)
        B, S = shape.global_batch, shape.seq_len

        def prefill_step(params, batch):
            cache = model.init_cache(B, S)
            out = model.apply(params, batch, mode="prefill", cache=cache)
            logits = model.logits_of(params, out["last_hidden"])
            return jnp.argmax(logits, -1).astype(jnp.int32), out["cache"]

        return Cell(arch, shape, mesh, prefill_step, sp=sp,
                    in_avals=(params_avals, batch_avals),
                    in_shardings=(params_spec, batch_spec),
                    out_shardings=(shd.batch_specs(
                        mesh, jax.ShapeDtypeStruct((B,), jnp.int32)),
                        cache_spec),
                    model_flops=mf)

    # decode (serving layout: TP-only weights, no per-step FSDP gathers)
    params_avals = _abstract_params(model)
    params_spec = shd.param_specs(mesh, params_avals, fsdp=serve_fsdp)
    cache_avals = model_zoo.cache_specs(cfg, shape)
    cache_spec = shd.cache_specs_tree(mesh, cache_avals)
    B = shape.global_batch

    def serve_step(params, cache, batch):
        out = model.apply(params, batch, mode="decode", cache=cache)
        logits = model.logits_of(params, out["hidden"][:, 0])
        return jnp.argmax(logits, -1).astype(jnp.int32), out["cache"]

    return Cell(arch, shape, mesh, serve_step, sp=sp,
                in_avals=(params_avals, cache_avals, batch_avals),
                in_shardings=(params_spec, cache_spec, batch_spec),
                out_shardings=(shd.batch_specs(
                    mesh, jax.ShapeDtypeStruct((B,), jnp.int32)),
                    cache_spec),
                model_flops=mf, donate=(1,))
