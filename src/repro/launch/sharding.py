"""Sharding rules: param/optimizer/batch/cache PartitionSpecs per arch.

Scheme (Megatron/MaxText-flavoured, adapted to the zoo):

  * TP (`model` axis): attention q-heads, MLP hidden, MoE experts (EP reuses
    the TP axis), RG-LRU recurrence width, RWKV heads, vocab;
  * FSDP (`data` axis): the d_model dimension of every weight (ZeRO-3-style;
    XLA inserts the all-gathers);
  * `pod` axis: pure data parallelism — params replicated across pods,
    batch sharded, gradient all-reduce crosses pods once per step;
  * GQA KV projections are REPLICATED across `model` (num_kv_heads ≤ 16
    never divides evenly; the small-KV rule);
  * decode KV caches: batch on the DP axes, sequence chunks on `model`
    (flash-decode sharding) — this is what makes decode_32k/long_500k fit.

Every rule degrades gracefully: an axis is only used when it divides the
dimension, otherwise that dim is replicated (e.g. whisper's 51865 vocab).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cfgs


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _fit(mesh, shape, want: Sequence[Any]) -> P:
    """Build a PartitionSpec, dropping axes that don't divide the dim."""
    spec = []
    for dim, axis in zip(shape, want):
        if axis is None:
            spec.append(None)
        elif dim % _axis_size(mesh, axis) == 0:
            spec.append(axis)
        else:
            spec.append(None)
    return P(*spec)


def _resolve(token, fsdp, tp):
    if token == "F":
        return fsdp
    if token == "T":
        return tp
    return token


def _key_of(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# (regex on the leaf path, want-spec builder given ndim); `F`=fsdp, `T`=tp.
# Leading stacked-layer axes (periods/b*, enc_layers, dec_layers) are padded
# with None by ndim alignment: the want list is right-aligned to the shape.
_PARAM_RULES: Tuple[Tuple[str, Tuple[Any, ...]], ...] = (
    (r"embed/w$",                       ("T", "F")),
    (r"lm_head/w$",                     ("F", "T")),
    (r"(pos_embed|enc_pos)/w$",         (None, "F")),
    # attention
    (r"attn/wq$",                       ("F", "T")),
    (r"x?attn/w[kv]$",                  ("F", None)),
    (r"attn/wo$",                       ("T", "F")),
    (r"xattn/wq$",                      ("F", "T")),
    (r"xattn/wo$",                      ("T", "F")),
    (r"attn/bq$",                       ("T",)),
    (r"attn/b[kvo]$",                   (None,)),
    # dense MLP
    (r"mlp/w[ig]$",                     ("F", "T")),
    (r"mlp/wo$",                        ("T", "F")),
    (r"mlp/b[ig]$",                     ("T",)),
    (r"mlp/bo$",                        (None,)),
    # MoE (EP on the model axis)
    (r"router/w$",                      ("F", None)),
    (r"experts/w[ig]$",                 ("T", "F", None)),
    (r"experts/wo$",                    ("T", None, "F")),
    (r"shared/w[ig]$",                  ("F", "T")),
    (r"shared/wo$",                     ("T", "F")),
    (r"shared/b[ig]$",                  ("T",)),
    (r"shared/bo$",                     (None,)),
    # RG-LRU
    (r"rec/in_(x|gate)$",               ("F", "T")),
    (r"rec/out$",                       ("T", "F")),
    (r"rec/conv_w$",                    (None, "T")),
    (r"rec/(conv_b|a_param|[ir]_gate_[wb])$", ("T",)),
    # RWKV6
    (r"tmix/w[rkvgw]$",                 ("F", "T")),
    (r"tmix/ww$",                       ("F", "T")),
    (r"tmix/wo$",                       ("T", "F")),
    (r"tmix/u$",                        ("T", None)),
    (r"tmix/(mu_.|w0|gn_scale|gn_bias)$", (None,)),
    (r"cmix/wk$",                       ("F", "T")),
    (r"cmix/wv$",                       ("T", "F")),
    (r"cmix/mu_k$",                     (None,)),
)


def param_spec(mesh, key: str, shape, *, fsdp, tp) -> P:
    # optimizer moments share the param layout
    key = re.sub(r"^(mu|nu)/", "", key)
    for pat, want in _PARAM_RULES:
        if re.search(pat, key):
            aligned: list = [None] * (len(shape) - len(want)) + [
                {"F": fsdp, "T": tp, None: None}[w] for w in want]
            return _fit(mesh, shape, aligned)
    return P()          # norms, scalars, anything unmatched: replicate


def state_specs(mesh, state_tree) -> Any:
    """PartitionSpecs for a TrainState tree (params + adamw moments)."""
    fsdp, tp = "data", "model"

    def one(path, leaf):
        key = _key_of(path)
        key = re.sub(r"^(params|opt_state)/", "", key)
        if key in ("count", "step"):
            return P()
        return param_spec(mesh, key, np.shape(leaf), fsdp=fsdp, tp=tp)

    return jax.tree_util.tree_map_with_path(one, state_tree)


def param_specs(mesh, params_tree, *, fsdp: Any = "data") -> Any:
    """Param specs.  Training uses fsdp="data" (ZeRO-3 layout).  Serving
    passes fsdp=None: weights TP-sharded only and replicated across the DP
    axes — per-step FSDP all-gathers are pure waste when there is no
    optimizer state to co-locate (observed: ~7 GB/step of weight gathers
    on the 35B decode cell)."""
    def one(path, leaf):
        return param_spec(mesh, _key_of(path), np.shape(leaf),
                          fsdp=fsdp, tp="model")
    return jax.tree_util.tree_map_with_path(one, params_tree)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------


def batch_specs(mesh, batch_tree) -> Any:
    """Shard the leading (batch) dim over all DP axes (divisibility-gated)."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_ax: Any = dp if len(dp) > 1 else dp[0]

    def one(path, leaf):
        shape = np.shape(leaf)
        if not shape:
            return P()
        return _fit(mesh, shape, [dp_ax] + [None] * (len(shape) - 1))

    return jax.tree_util.tree_map_with_path(one, batch_tree)


_CACHE_RULES: Tuple[Tuple[str, Tuple[Any, ...]], ...] = (
    # attention KV: [..., B, S, KV, hd] — batch on DP, sequence on model
    (r"/x?k$|/x?v$|^k$|^v$|^xk$|^xv$",  ("B", "S", None, None)),
    # RG-LRU state: h [B, W], conv [B, cw-1, W]
    (r"/h$",                            ("B", "S")),
    (r"/conv$",                         ("B", None, "S")),
    # RWKV state: s [B, H, hd, hd], shift [B, D]
    (r"/s$",                            ("B", "S", None, None)),
    (r"/shift_[tc]$",                   ("B", None)),
    (r"len$",                           ("B",)),
)


def cache_specs_tree(mesh, cache_tree) -> Any:
    """KV/state cache sharding: batch over DP axes, the large state axis
    (sequence / recurrence width / heads) over `model`."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_ax: Any = dp if len(dp) > 1 else dp[0]
    sub = {"B": dp_ax, "S": "model", None: None}

    def one(path, leaf):
        key = _key_of(path)
        shape = np.shape(leaf)
        if not shape:
            return P()
        for pat, want in _CACHE_RULES:
            if re.search(pat, key):
                aligned = [None] * (len(shape) - len(want)) + [
                    sub[w] for w in want]
                return _fit(mesh, shape, aligned)
        return P()

    return jax.tree_util.tree_map_with_path(one, cache_tree)


# ---------------------------------------------------------------------------
# convenience
# ---------------------------------------------------------------------------


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
