"""Serving driver: run AISQL queries against real JAX inference engines.

    PYTHONPATH=src python -m repro.launch.serve \
        --archs proxy-8b oracle-70b --replicas 2 \
        --sql "SELECT * FROM reviews AS r WHERE AI_FILTER(...)"

Stands up the Cortex-platform analogue (engines + scheduler + API service)
on smoke-size models, loads the synthetic datasets into a catalog, and
executes queries end-to-end with AI-aware optimization.
"""
from __future__ import annotations

import argparse

from repro.core import AisqlEngine, Catalog, CascadeConfig, ExecConfig
from repro.data import datasets as D
from repro.inference.api import make_engine_client
from repro.tables.table import Table


DEFAULT_SQL = ("SELECT * FROM reviews AS r WHERE "
               "AI_FILTER(PROMPT('positive review? {0}', r.text)) LIMIT 5")


def build_catalog(rows: int = 64) -> Catalog:
    tables = {
        "reviews": D.cascade_table("IMDB", rows=rows),
        "articles": D.nyt_articles(rows),
    }
    left, right, _ = D.join_tables("AGNEWS_100")
    tables["news"] = left
    tables["topics"] = right
    return Catalog(tables)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="+", default=["proxy-8b", "oracle-70b"])
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--rows", type=int, default=48)
    ap.add_argument("--cascade", action="store_true")
    ap.add_argument("--sql", default=DEFAULT_SQL)
    ap.add_argument("--explain", action="store_true")
    args = ap.parse_args(argv)

    client = make_engine_client(tuple(args.archs), replicas=args.replicas)
    engine = AisqlEngine(
        build_catalog(args.rows), client,
        executor=ExecConfig(use_cascade=args.cascade,
                            cascade=CascadeConfig(batch_size=32,
                                                  min_samples=8)))
    if args.explain:
        print(engine.explain(args.sql))
        return 0
    out = engine.sql(args.sql)
    print(out)
    for i in range(min(out.num_rows, 10)):
        print(" ", {k: str(v)[:60] for k, v in out.row(i).items()})
    rep = engine.last_report
    print(f"-- {rep.ai_calls} LLM calls, {rep.ai_credits:.6f} credits, "
          f"{rep.wall_seconds:.2f}s wall")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
