"""Fig 10: AI-predicate placement wrt joins — output/input ratio 0.1..2.0.

Compares Always Push-down (Snowflake default), Always Pull-up, and
AI-aware placement on a join whose output cardinality is swept.
"""
from __future__ import annotations

from benchmarks.common import fmt_table, model_clock, save_result
from repro.core import AisqlEngine, Catalog, OptimizerConfig
from repro.data import datasets as D
from repro.inference.api import make_simulated_client

MODES = ("always_pushdown", "always_pullup", "ai_aware")


def run(n_left: int = 400, seed: int = 0):
    out = []
    for ratio in (0.1, 0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 2.0):
        left, right = D.nyt_join_pair(n_left, out_in_ratio=ratio, seed=seed)
        cat = Catalog({"ny_articles_v1": left, "ny_meta": right})
        sql = ("SELECT * FROM ny_articles_v1 AS a JOIN ny_meta AS m "
               "ON a.key = m.key AND "
               "AI_FILTER(PROMPT('relevant? {0}', a.body))")
        row = {"out_in_ratio": ratio}
        clocks = {}
        for mode in MODES:
            client = make_simulated_client()
            eng = AisqlEngine(cat, client,
                              optimizer=OptimizerConfig(mode=mode))
            eng.sql(sql)
            clocks[mode] = model_clock(client)
            row[f"t_{mode}"] = round(clocks[mode], 3)
        best = min(clocks.values())
        row["ai_aware_is_best"] = clocks["ai_aware"] <= best + 1e-9
        out.append(row)
    return out


def main():
    rows = run()
    print("== Fig 10: AI predicate placement vs joins ==")
    print(fmt_table(rows, ["out_in_ratio", "t_always_pushdown",
                           "t_always_pullup", "t_ai_aware",
                           "ai_aware_is_best"]))
    assert all(r["ai_aware_is_best"] for r in rows), \
        "AI-aware placement must dominate across the sweep"
    save_result("bench_join_placement", {"rows": rows})
    return rows


if __name__ == "__main__":
    main()
