"""Observability overhead + reconciliation gates.

Three gated claims about the tracing/metrics subsystem (`repro.obs`):

  * **overhead**: a fully-traced replay of the mixed multi-tenant
    workload stays within 5% wall time of an untraced twin (best-of-N
    on both sides), and its per-tenant row digests are byte-identical
    to the untraced run — observation never changes results;
  * **reconciliation**: summing the ``credits`` / token attrs over
    every ``dispatch.replica`` span in the trace ring equals the
    backends' own billing meters to 1e-9 relative — under injected
    transient faults, because failed attempts carry no credits;
  * **wire round-trip**: over a real loopback socket, ``/v1/metrics``
    parses with the minimal Prometheus parser and carries every
    declared family with samples, ``/v1/trace/<query_id>`` returns the
    span tree of a query just executed, and the rows that came over
    the wire from the traced server are byte-identical to an
    identically-seeded untraced engine's.

    PYTHONPATH=src python -m benchmarks.bench_obs [--quick] [--wire-smoke]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional

from benchmarks.common import fmt_table, save_result
from repro.obs import (METRIC_FAMILIES, Observability, TickClock,
                       parse_prometheus_text, walk_spans)

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))

from replay import (TraceConfig, build_catalog, generate_trace,  # noqa: E402
                    replay)

SEED = 0


def _run(trace_cfg: TraceConfig, *, traced: bool, workers: int,
         fault_rate: float = 0.0, burst_every: int = 0,
         burst_len: int = 0):
    """One replay run; returns ``(ReplayReport, Observability)``."""
    trace = generate_trace(trace_cfg)
    catalog = build_catalog(trace_cfg)
    obs = Observability(enabled=traced, clock=TickClock,
                        ring_size=len(trace))
    rep = replay(trace, catalog, workers=workers, seed=trace_cfg.seed,
                 fault_rate=fault_rate, fault_burst_every=burst_every,
                 fault_burst_len=burst_len, obs=obs)
    return rep, obs


# ---------------------------------------------------------------------------
# 1) overhead: traced vs untraced twins, best-of-N
# ---------------------------------------------------------------------------


def bench_overhead(trace_cfg: TraceConfig, *, iters: int,
                   workers: int) -> Dict[str, Any]:
    runs: Dict[bool, List[float]] = {False: [], True: []}
    digests: Dict[bool, Dict[str, str]] = {}
    for traced in (False, True):
        for _ in range(iters):
            rep, _obs = _run(trace_cfg, traced=traced, workers=workers)
            runs[traced].append(rep.wall_s)
            digests[traced] = {t: o.rows_sha256
                               for t, o in rep.per_tenant.items()}
    best_off, best_on = min(runs[False]), min(runs[True])
    overhead = best_on / best_off - 1.0
    rows_identical = digests[False] == digests[True]
    print(fmt_table([
        {"mode": "untraced", "best_wall_s": f"{best_off:.3f}",
         "runs": iters},
        {"mode": "traced", "best_wall_s": f"{best_on:.3f}",
         "runs": iters},
    ], ["mode", "best_wall_s", "runs"]))
    print(f"tracing overhead: {overhead:+.2%} (gate < 5%); "
          f"rows identical: {rows_identical}")
    assert rows_identical, \
        "tracing changed result rows — observation must be passive"
    assert overhead < 0.05, \
        f"tracing overhead {overhead:.2%} exceeds the 5% gate"
    return {"overhead_frac": overhead, "untraced_best_s": best_off,
            "traced_best_s": best_on, "rows_identical": rows_identical}


# ---------------------------------------------------------------------------
# 2) reconciliation: replica-span sums vs the billing meters
# ---------------------------------------------------------------------------


def bench_reconcile(trace_cfg: TraceConfig, *,
                    workers: int) -> Dict[str, Any]:
    rep, obs = _run(trace_cfg, traced=True, workers=workers,
                    fault_rate=0.05, burst_every=40, burst_len=4)
    span_credits = 0.0
    span_tokens = 0
    attempts = ok = 0
    for qid in obs.ring.ids():
        for span in walk_spans(obs.ring.get(qid)):
            if span["kind"] != "dispatch.replica":
                continue
            attempts += 1
            if span["attrs"].get("outcome") == "ok":
                ok += 1
                span_credits += span["attrs"]["credits"]
                span_tokens += (span["attrs"]["tokens_in"]
                                + span["attrs"]["tokens_out"])
    backend = rep.backend_credits
    assert backend is not None and backend > 0
    rel = abs(span_credits - backend) / backend
    # independent token path: the scheduler's registry families
    reg_tokens = sum(
        s["value"] for s in obs.registry.snapshot()
        ["aisql_ai_tokens_total"]["series"])
    print(f"replica spans: {attempts} attempts, {ok} ok, "
          f"{attempts - ok} faulted ({rep.scheduler_retries} scheduler "
          f"retries, {rep.retries} pipeline retries)")
    print(f"credits: spans {span_credits:.9g} vs backends "
          f"{backend:.9g} (rel err {rel:.2e}, gate 1e-9)")
    print(f"tokens: spans {span_tokens} vs registry {int(reg_tokens)}")
    assert rel <= 1e-9, \
        f"span credit sum diverges from backend meter: rel err {rel:.2e}"
    assert span_tokens == int(reg_tokens), \
        "span token sum diverges from the registry token counters"
    return {"span_credits": span_credits, "backend_credits": backend,
            "credit_rel_err": rel, "replica_attempts": attempts,
            "replica_ok": ok, "span_tokens": span_tokens}


# ---------------------------------------------------------------------------
# 3) wire round-trip: /v1/metrics + /v1/trace + row fidelity
# ---------------------------------------------------------------------------


def bench_wire(trace_cfg: TraceConfig) -> Dict[str, Any]:
    from repro.core import ServingEngine
    from repro.serve import AisqlHttpClient, AisqlHttpServer

    trace = generate_trace(trace_cfg)
    # traced engine behind a real socket
    obs = Observability(clock=TickClock, ring_size=len(trace))
    from repro.core import ServingConfig
    eng = ServingEngine.simulated(build_catalog(trace_cfg),
                                  seed=trace_cfg.seed,
                                  cfg=ServingConfig(obs=obs))
    wire_rows: Dict[int, str] = {}
    qids: List[str] = []
    with AisqlHttpServer(eng) as srv:
        client = AisqlHttpClient(srv.host, srv.port)
        for i, ev in enumerate(trace):
            out = client.query(ev.sql)
            wire_rows[i] = json.dumps([out["columns"], out["rows"]],
                                      sort_keys=True)
            qids.append(out["query_id"])
        # metrics: must parse, and every declared family must be present
        families = parse_prometheus_text(client.metrics())
        missing = [f for f in METRIC_FAMILIES
                   if not any(k == f or k.startswith(f + "_")
                              for k in families)]
        # trace: the last query's span tree is still in the ring
        tree = client.trace(qids[-1])["trace"]
        client.close()
    eng.close()
    assert not missing, f"families absent from /v1/metrics: {missing}"
    assert tree["kind"] == "query" and tree["children"], \
        "/v1/trace returned a malformed span tree"
    # untraced twin, identical seed, direct library execution
    twin = ServingEngine.simulated(
        build_catalog(trace_cfg), seed=trace_cfg.seed,
        cfg=ServingConfig(obs=Observability(enabled=False)))
    from repro.serve.http import table_rows
    identical = 0
    try:
        for i, ev in enumerate(trace):
            table = twin.submit(ev.tenant, ev.sql).result(timeout=120)
            cols, rows = table_rows(table)
            if json.dumps([cols, rows], sort_keys=True) == wire_rows[i]:
                identical += 1
    finally:
        twin.close()
    print(f"wire: {len(trace)} queries, {identical} row-identical to "
          f"the untraced twin; {len(families)} metric series names, "
          f"trace fetched for {qids[-1]}")
    assert identical == len(trace), \
        f"only {identical}/{len(trace)} wire results matched the twin"
    return {"wire_queries": len(trace), "wire_identical": identical,
            "metric_names": len(families)}


# ---------------------------------------------------------------------------


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload, fewer overhead iterations")
    ap.add_argument("--wire-smoke", action="store_true",
                    help="run only the wire round-trip gate (CI smoke)")
    args = ap.parse_args(argv)

    wire_cfg = TraceConfig(seed=SEED, sessions=12, tenants=2, rows=512,
                           queries_per_session=(1, 2))
    if args.wire_smoke:
        payload: Dict[str, Any] = {"mode": "wire-smoke"}
        payload.update(bench_wire(wire_cfg))
        save_result("bench_obs", payload)
        return 0

    if args.quick:
        load_cfg = TraceConfig(seed=SEED, sessions=120, tenants=4,
                               rows=1024)
        iters = 2
    else:
        load_cfg = TraceConfig(seed=SEED, sessions=400, tenants=8,
                               rows=2048)
        iters = 3

    payload = {"mode": "quick" if args.quick else "full",
               "sessions": load_cfg.sessions}
    print("== overhead: traced vs untraced twins ==")
    payload.update(bench_overhead(load_cfg, iters=iters, workers=4))
    print("\n== reconciliation: replica spans vs billing meters ==")
    payload.update(bench_reconcile(load_cfg, workers=4))
    print("\n== wire round-trip over a loopback socket ==")
    payload.update(bench_wire(wire_cfg))
    path = save_result("bench_obs", payload)
    print(f"\nresults -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
