"""§5.4: AI aggregation short-circuit — latency reduction on small groups.

The paper reports an 86.1% latency reduction for AI_SUMMARIZE_AGG on
inputs that fit one context window.  We sweep group sizes and compare the
hierarchical fold (short_circuit=False) against the optimized path.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table, model_clock, save_result
from repro.core import AggConfig, AisqlEngine, Catalog, ExecConfig
from repro.data import datasets as D
from repro.inference.api import make_simulated_client


def run(seed: int = 0):
    rows = []
    for n in (4, 16, 64, 256, 1024):
        t = D.cascade_table("IMDB", rows=n, seed=seed)
        cat = Catalog({"reviews": t})
        sql = "SELECT AI_SUMMARIZE_AGG(r.text) FROM reviews AS r"
        res = {}
        for sc in (False, True):
            client = make_simulated_client(seed=seed)
            eng = AisqlEngine(cat, client, executor=ExecConfig(
                agg=AggConfig(short_circuit=sc)))
            eng.sql(sql)
            tel = eng.exec.agg_telemetry
            res[sc] = {"time_s": model_clock(client),
                       "llm_calls": tel.llm_calls,
                       "short_circuited": tel.short_circuited}
        reduction = 1 - res[True]["time_s"] / max(res[False]["time_s"], 1e-12)
        rows.append({
            "group_rows": n,
            "calls_fold": res[False]["llm_calls"],
            "calls_opt": res[True]["llm_calls"],
            "t_fold_s": round(res[False]["time_s"], 4),
            "t_opt_s": round(res[True]["time_s"], 4),
            "latency_reduction": f"{100 * reduction:.1f}%",
            "short_circuited": res[True]["short_circuited"],
        })
    return rows


def main():
    rows = run()
    print("== §5.4: AI_SUMMARIZE_AGG short-circuit ==")
    print(fmt_table(rows, ["group_rows", "calls_fold", "calls_opt",
                           "t_fold_s", "t_opt_s", "latency_reduction",
                           "short_circuited"]))
    print("paper: 86.1% latency reduction on small datasets")
    save_result("bench_agg_shortcircuit", {"rows": rows})
    return rows


if __name__ == "__main__":
    main()
