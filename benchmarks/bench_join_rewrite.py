"""Tables 3-4 / Fig 12: semantic-join -> AI_CLASSIFY rewrite on eight
benchmarks at the paper's cardinalities.

Baseline: cross join + per-pair AI_FILTER (O(L*R) calls).
Rewrite:  per-left-row multi-label AI_CLASSIFY (O(L) calls, chunked).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table, model_clock, save_result
from repro.core import AisqlEngine, Catalog, OptimizerConfig
from repro.data import datasets as D
from repro.inference.api import make_simulated_client


def _run_one(name: str, mode: str, seed: int = 0):
    left, right, spec = D.join_tables(name, seed=seed)
    cat = Catalog({"l": left, "r": right})
    sql = ("SELECT * FROM l JOIN r ON "
           f"AI_FILTER(PROMPT('{D.JOIN_PROMPTS[name]}', l.content, r.label))")
    truth = D.true_pairs_of(left, right)
    client = make_simulated_client(seed=seed)
    eng = AisqlEngine(cat, client, optimizer=OptimizerConfig(mode=mode))
    out = eng.sql(sql)
    pairs = set(zip((int(x) for x in out.column("l.id")),
                    (str(x) for x in out.column("r.label"))))
    m = D.pair_metrics(pairs, truth)
    return {"calls": eng.last_report.ai_calls,
            "time_s": model_clock(client), **m}


def run(seed: int = 0):
    rows = []
    for name, spec in D.JOIN_DATASETS.items():
        base = _run_one(name, "none", seed)
        rw = _run_one(name, "ai_aware", seed)
        rows.append({
            "dataset": name, "L": spec.left_rows, "R": spec.right_rows,
            "calls_base": base["calls"], "calls_rw": rw["calls"],
            "t_base": round(base["time_s"], 2),
            "t_rw": round(rw["time_s"], 2),
            "speedup": round(base["time_s"] / rw["time_s"], 2),
            "P_base": round(base["precision"], 3),
            "R_base": round(base["recall"], 3),
            "f1_base": round(base["f1"], 3),
            "P_rw": round(rw["precision"], 3),
            "R_rw": round(rw["recall"], 3),
            "f1_rw": round(rw["f1"], 3),
        })
    mean = {
        "dataset": "MEAN",
        "t_base": round(np.mean([r["t_base"] for r in rows]), 2),
        "t_rw": round(np.mean([r["t_rw"] for r in rows]), 2),
        "speedup": round(np.mean([r["speedup"] for r in rows]), 2),
        "f1_base": round(np.mean([r["f1_base"] for r in rows]), 3),
        "f1_rw": round(np.mean([r["f1_rw"] for r in rows]), 3),
    }
    return rows + [mean]


def main():
    rows = run()
    print("== Tables 3-4 / Fig 12: semantic-join rewrite (8 datasets) ==")
    print(fmt_table(rows, ["dataset", "L", "R", "calls_base", "calls_rw",
                           "speedup", "P_base", "R_base", "f1_base",
                           "P_rw", "R_rw", "f1_rw"]))
    print("paper: 15.2-69.5x speedups (mean 30.7x), mean F1 0.412 -> 0.596")
    save_result("bench_join_rewrite", {"rows": rows})
    return rows


if __name__ == "__main__":
    main()
