"""Concurrent multi-tenant serving vs serial per-query execution.

Replays a workload with the production shape — 8 tenants issuing
overlapping queries where the same AI predicates recur across queries
(and across tenants) — through two runtimes:

  * **serial**: each query on a fresh, isolated `AisqlEngine` with its
    own pipelined client (within-query batching, zero cross-query
    sharing) — the pre-serving baseline;
  * **serving**: one `ServingEngine` with 8 worker threads, all sessions
    sharing one `RequestPipeline` (cross-query coalescing + dedup + the
    TTL'd LRU result cache) and one `StatsStore`.

The acceptance gate: the serving runtime answers the same workload with
**>= 2x fewer LLM dispatches** at identical per-query result rows.  A
second pass replays the workload under injected transient faults
(``fault_rate=0.2``) and checks rows stay identical while retries are
metered in the `ServingReport`.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.common import fmt_table, model_clock, save_result
from repro.core import (AisqlEngine, Catalog, ServingConfig, ServingEngine)
from repro.data import datasets as D
from repro.inference.api import make_simulated_client
from repro.inference.pipeline import PipelineConfig

SEED = 0
TENANTS = 8

_TEMPLATES = [
    "SELECT * FROM articles AS a WHERE "
    "AI_FILTER(PROMPT('broad topic? {0}', a.headline))",
    "SELECT a.id FROM articles AS a WHERE "
    "AI_FILTER(PROMPT('narrow topic? {0}', a.summary))",
    "SELECT * FROM articles AS b WHERE "
    "AI_FILTER(PROMPT('broad topic? {0}', b.headline)) AND b.id < 200",
    "SELECT r.id, AI_CLASSIFY(PROMPT('sentiment of {0}', r.text), "
    "['positive','negative']) AS sentiment FROM reviews AS r WHERE "
    "AI_FILTER(PROMPT('positive sentiment? {0}', r.text))",
    "SELECT * FROM reviews AS r WHERE "
    "AI_FILTER(PROMPT('positive sentiment? {0}', r.text)) AND r.id < 150",
    "SELECT * FROM articles AS a WHERE "
    "AI_FILTER(PROMPT('narrow topic? {0}', a.summary)) LIMIT 5",
]


def make_catalog(rows: int) -> Catalog:
    return Catalog({
        "articles": D.skewed_articles(rows, seed=3),
        "reviews": D.cascade_table("IMDB", rows=rows, seed=1),
    })


def make_workload(repeats: int) -> List[Tuple[str, str]]:
    """Round-robin the template corpus over the tenants ``repeats``
    times — every predicate recurs many times across tenants, the shape
    cross-query reuse exists for."""
    out = []
    for rep in range(repeats):
        for i, sql in enumerate(_TEMPLATES):
            out.append((f"tenant-{(rep * len(_TEMPLATES) + i) % TENANTS}",
                        sql))
    return out


def canon_rows(table):
    cols = table.column_names
    return sorted(tuple(str(table.column(c)[i]) for c in cols)
                  for i in range(table.num_rows))


def run_serial(workload, rows):
    t0 = time.perf_counter()
    results, dispatched, credits, model_s = [], 0, 0.0, 0.0
    for _tenant, sql in workload:
        client = make_simulated_client(seed=SEED, pipelined=True)
        eng = AisqlEngine(make_catalog(rows), client)
        results.append(canon_rows(eng.sql(sql)))
        dispatched += client.pipeline.stats.dispatched
        credits += client.ai_credits
        model_s += model_clock(client)     # batch-amortized engine seconds
    return {
        "config": "serial (isolated engines)", "queries": len(workload),
        "dispatched": dispatched, "dedup_hits": 0, "cross_query": 0,
        "credits": round(credits, 5), "model_s": round(model_s, 2),
        "wall_s": round(time.perf_counter() - t0, 2),
    }, results


def run_serving(workload, rows, *, fault_rate=0.0, timeout_rate=0.0,
                max_batch=512):
    t0 = time.perf_counter()
    cfg = ServingConfig(workers=8, pipeline=PipelineConfig(
        max_batch=max_batch, cache_ttl_s=300.0, retry_backoff_s=0.0005))
    with ServingEngine.simulated(make_catalog(rows), seed=SEED,
                                 fault_rate=fault_rate,
                                 timeout_rate=timeout_rate, cfg=cfg) as srv:
        tickets = srv.run_all(workload)
        results = [canon_rows(t.result()) for t in tickets]
        rep = srv.report()
        model_s = _model_seconds(srv)
    label = ("serving (8 workers, shared pipeline)" if not fault_rate else
             f"serving + faults (rate={fault_rate})")
    return {
        "config": label, "queries": len(workload),
        "dispatched": rep.dispatched_requests,
        "dedup_hits": rep.dedup_hits, "cross_query": rep.cross_query_hits,
        "credits": round(rep.total_credits, 5),
        "model_s": round(model_s, 2),
        "wall_s": round(time.perf_counter() - t0, 2),
    }, results, rep


def _model_seconds(srv) -> float:
    total, seen = 0.0, set()
    for reps in srv.scheduler._replicas.values():
        for e in reps:
            if id(e) not in seen and hasattr(e, "clock_s"):
                total += e.clock_s
                seen.add(id(e))
    return total


def main(rows: int = 240, repeats: int = 4):
    workload = make_workload(repeats)
    serial_row, serial_res = run_serial(workload, rows)
    serving_row, serving_res, rep = run_serving(workload, rows)
    assert serving_res == serial_res, \
        "serving run diverged from serial per-query rows"
    # small dispatch batches in the faulty pass: each dispatch rolls the
    # fault die once, so more batches = a properly exercised retry path
    faulty_row, faulty_res, faulty_rep = run_serving(workload, rows,
                                                     fault_rate=0.2,
                                                     timeout_rate=0.05,
                                                     max_batch=32)
    assert faulty_res == serial_res, \
        "fault-injected run diverged from fault-free rows"
    assert faulty_rep.retries + faulty_rep.scheduler_retries > 0, \
        "fault injection produced no visible retries"

    table = [serial_row, serving_row, faulty_row]
    print("== concurrent multi-tenant serving vs serial execution ==")
    print(fmt_table(table, ["config", "queries", "dispatched", "dedup_hits",
                            "cross_query", "credits", "model_s", "wall_s"]))
    speedup = serial_row["dispatched"] / max(serving_row["dispatched"], 1)
    credit_win = serial_row["credits"] / max(serving_row["credits"], 1e-12)
    print(f"\ncross-query sharing: {speedup:.2f}x fewer LLM dispatches, "
          f"{credit_win:.2f}x fewer credits at identical per-query rows")
    print(rep.render())
    print("\nfault-injected replay (rows still identical):")
    print(faulty_rep.render())
    assert speedup >= 2.0, \
        f"expected >= 2x fewer dispatches vs serial, got {speedup:.2f}x"
    save_result("bench_concurrent", {
        "rows": table, "dispatch_speedup": speedup,
        "credit_win": credit_win,
        "serving": {"retries": rep.retries,
                    "scheduler_retries": rep.scheduler_retries,
                    "cross_query_hits": rep.cross_query_hits},
        "faulty": {"retries": faulty_rep.retries,
                   "scheduler_retries": faulty_rep.scheduler_retries,
                   "scheduler_timeouts": faulty_rep.scheduler_timeouts,
                   "total_credits": faulty_rep.total_credits},
    })
    return table


if __name__ == "__main__":
    main()
