"""Semantic-index benchmark: index-assisted join blocking + kernel gate.

The workload where blocking pays hardest: a hybrid-join corpus with a
*large* label universe (|R| far beyond one AI_CLASSIFY context window),
so the §5.3 classification rewrite needs ``ceil(|R| / chunk)`` calls per
left row while the index narrows each row to ``k`` kNN candidates — one
verification call — for near-zero embedding credits.

Gated assertions (CI runs this):

  * the index-assisted semantic join dispatches **≥5× fewer LLM calls**
    than the classification rewrite,
  * at **identical result rows** (zero add-noise corpus: verification
    draws are per-(row,label) deterministic, so candidate pruning can
    only remove calls, never change decisions),
  * and the Pallas ``similarity_topk`` kernel matches its numpy
    reference in interpret mode on the benchmark's own vectors.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table, save_result
from repro.core import (AisqlEngine, Catalog, OptimizerConfig,
                        SemIndexConfig)
from repro.data import datasets as D
from repro.inference.api import make_simulated_client

PROMPT = "Document {0} is tagged with topic {1}"

SPEC = D.JoinSpec(
    name="HYBRIDX", left_rows=120, right_rows=512, kind="category",
    labels_per_left=1.2, doc_words=60, label_words=4,
    fp_bias=0.05, fn_bias=0.1, cls_drop=0.35, cls_adds=0.0)

# context-window chunking for both contenders: 512 labels at 50 per call
# puts the rewrite at ceil(512/50) = 11 calls per left row, while the
# index's 16 candidates stay a single call
OPT = OptimizerConfig(max_labels_per_call=50)


def _pairs(out):
    return set(zip((int(x) for x in out.column("l.id")),
                   (str(x) for x in out.column("r.label"))))


def run(seed: int = 0):
    left, right, spec = D.join_tables(seed=seed, spec=SPEC)
    cat = Catalog({"l": left, "r": right})
    sql = ("SELECT * FROM l JOIN r ON "
           f"AI_FILTER(PROMPT('{PROMPT}', l.content, r.label))")
    rows = []

    # -- baseline: the §5.3 classification rewrite ---------------------
    client_c = make_simulated_client(seed=seed)
    eng_c = AisqlEngine(cat, client_c, optimizer=OPT)
    out_c = eng_c.sql(sql)
    rep_c = eng_c.last_report
    assert "SemanticJoinClassify" in rep_c.plan, rep_c.plan
    rows.append({"strategy": "classify-rewrite", "calls": rep_c.ai_calls,
                 "credits": round(rep_c.ai_credits, 4),
                 "rows": out_c.num_rows})

    # -- index-assisted: offline build, then cold and warm queries -----
    cfg = SemIndexConfig(impl="interpret", join_k=32, nlist=32, nprobe=8)
    client_i = make_simulated_client(seed=seed)
    eng_i = AisqlEngine(cat, client_i, optimizer=OPT, semindex=cfg)
    mgr = eng_i.semindex
    # offline index build over the label column (amortized across every
    # query that joins against it; reported, not charged to the query)
    b0 = client_i.ai_calls
    labels = [str(v) for v in right.column("label")]
    mgr.ensure_index(client_i, "r.label", labels,
                     metadata=[{"embed_anchor": u} for u in labels])
    build_calls = client_i.ai_calls - b0
    build_credits = client_i.ai_credits
    rows.append({"strategy": "index-build (offline)", "calls": build_calls,
                 "credits": round(build_credits, 4), "rows": 0})

    out_i = eng_i.sql(sql)
    rep_i = eng_i.last_report
    assert "SemanticJoinIndex" in rep_i.plan, rep_i.plan
    rows.append({"strategy": "index-join (cold)", "calls": rep_i.ai_calls,
                 "credits": round(rep_i.ai_credits, 4),
                 "rows": out_i.num_rows})

    out_w = eng_i.sql(sql)
    rep_w = eng_i.last_report
    rows.append({"strategy": "index-join (warm)", "calls": rep_w.ai_calls,
                 "credits": round(rep_w.ai_credits, 4),
                 "rows": out_w.num_rows})

    # -- gates ---------------------------------------------------------
    assert _pairs(out_i) == _pairs(out_c), \
        "index-assisted join changed the result rows"
    assert _pairs(out_w) == _pairs(out_c)
    ratio_cold = rep_c.ai_calls / max(rep_i.ai_calls, 1)
    ratio_warm = rep_c.ai_calls / max(rep_w.ai_calls, 1)
    assert ratio_cold >= 5.0, \
        (f"index join must dispatch >=5x fewer LLM calls than the "
         f"rewrite, got {ratio_cold:.2f}x "
         f"({rep_c.ai_calls} vs {rep_i.ai_calls})")

    # kernel parity gate on the benchmark's own embedding matrix
    from repro.kernels.similarity_topk.ops import similarity_topk
    model = mgr.model_for(client_i)
    lvec = np.stack([v for v in mgr.store.get(
        model, [str(t) for t in left.column("content")],
        dim=mgr.cfg.dim) if v is not None])
    rvec, _ = mgr.store.column_matrix("r.label")
    v_int, i_int = similarity_topk(lvec, rvec, 16, impl="interpret")
    v_ref, i_ref = similarity_topk(lvec, rvec, 16, impl="reference")
    np.testing.assert_array_equal(np.asarray(i_int), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(v_int), np.asarray(v_ref),
                               rtol=2e-4, atol=2e-4)

    summary = {
        "rows": rows,
        "ratio_cold": round(ratio_cold, 2),
        "ratio_warm": round(ratio_warm, 2),
        "credit_ratio": round(rep_c.ai_credits
                              / max(rep_i.ai_credits, 1e-12), 1),
        "trace": [t for t in rep_i.optimizer_trace if "rewrite" in t],
    }
    return summary


def main():
    s = run()
    print("== semantic index: join blocking vs classification rewrite ==")
    print(fmt_table(s["rows"], ["strategy", "calls", "credits", "rows"]))
    print(f"cold {s['ratio_cold']}x / warm {s['ratio_warm']}x fewer LLM "
          f"calls, {s['credit_ratio']}x fewer credits, identical result "
          "rows; similarity_topk interpret == numpy reference")
    save_result("bench_index", s)
    return s


if __name__ == "__main__":
    main()
