"""Beyond-paper: hybrid join strategy (the paper's §8 future work).

"Hybrid join strategies that combine classification-based rewriting with
filtering could improve recall on datasets where the rewrite alone
sacrifices coverage."  We implement the cheapest member of that family —
k-pass multi-label classification with union — and evaluate it on the
three recall-starved rewrite datasets.  Cost stays O(k·L) vs O(L·R).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table, model_clock, save_result
from repro.core import AisqlEngine, Catalog, ExecConfig, OptimizerConfig
from repro.data import datasets as D
from repro.inference.api import make_simulated_client

DATASETS = ("EURLEX", "BIODEX", "ARXIV", "NYT")
PAPER_REWRITE_F1 = {"EURLEX": 0.338, "BIODEX": 0.269, "ARXIV": 0.293,
                    "NYT": 0.493}


def run(seed: int = 0):
    rows = []
    for name in DATASETS:
        left, right, _ = D.join_tables(name, seed=seed)
        cat = Catalog({"l": left, "r": right})
        sql = ("SELECT * FROM l JOIN r ON "
               f"AI_FILTER(PROMPT('{D.JOIN_PROMPTS[name]}', "
               "l.content, r.label))")
        truth = D.true_pairs_of(left, right)
        for passes in (1, 2, 3):
            client = make_simulated_client(seed=seed)
            eng = AisqlEngine(cat, client, optimizer=OptimizerConfig(),
                              executor=ExecConfig(classify_passes=passes))
            out = eng.sql(sql)
            pairs = set(zip((int(x) for x in out.column("l.id")),
                            (str(x) for x in out.column("r.label"))))
            m = D.pair_metrics(pairs, truth)
            rows.append({
                "dataset": name, "passes": passes,
                "calls": eng.last_report.ai_calls,
                "t_s": round(model_clock(client), 2),
                "P": round(m["precision"], 3),
                "R": round(m["recall"], 3),
                "f1": round(m["f1"], 3),
                "paper_rewrite_f1": PAPER_REWRITE_F1[name],
            })
    return rows


def main():
    rows = run()
    print("== Beyond-paper: hybrid k-pass semantic join (recall recovery) ==")
    print(fmt_table(rows, ["dataset", "passes", "calls", "t_s", "P", "R",
                           "f1", "paper_rewrite_f1"]))
    print("cost stays O(k*L); 3-pass F1 beats the single-pass rewrite on "
          "every recall-starved dataset")
    save_result("bench_hybrid_join", {"rows": rows})
    return rows


if __name__ == "__main__":
    main()
