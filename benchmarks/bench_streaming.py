"""Partitioned streaming execution vs materialize-then-truncate.

Workload 1 — **LIMIT-bounded AI_FILTER** (the paper's "stop buying
inference you don't need" case): ``SELECT * … WHERE AI_FILTER(…) LIMIT
k`` over 2000 articles.  The baseline executor materializes the full
filter before `Limit` truncates, paying one oracle call per table row;
the partition-pull loop drains ``partition_rows`` morsels until k
surviving rows exist and cancels the unsubmitted partitions.  Identical
result rows are asserted; the acceptance bar is **≥2× fewer LLM calls
and credits**.

Workload 2 — **semantic top-k** (ORDER BY AI_SCORE … DESC LIMIT k): the
unfused plan scores every row with the ordering model and truncates;
the fused `TopK` prefilters with the cheap proxy and escalates only
``topk_candidate_factor × k`` candidates to the oracle.

Artifacts -> results/bench_streaming.json.
"""
from __future__ import annotations

from benchmarks.common import fmt_table, model_clock, save_result
from repro.core import AisqlEngine, Catalog, ExecConfig, OptimizerConfig
from repro.data import datasets as D
from repro.inference.api import make_simulated_client

LIMIT_SQL = ("SELECT * FROM ny_articles AS a WHERE "
             "AI_FILTER(PROMPT('is this article newsworthy? {0}', a.body)) "
             "LIMIT 10")
TOPK_SQL = ("SELECT a.id FROM ny_articles AS a ORDER BY "
            "AI_SCORE(PROMPT('how newsworthy is this article? {0}', a.body)) "
            "DESC LIMIT 10")


def _run(cat, sql, *, pipelined=False, partitioned=False,
         lookahead=1, topk_fusion=True):
    client = make_simulated_client(pipelined=pipelined)
    eng = AisqlEngine(
        cat, client,
        optimizer=OptimizerConfig(enable_topk_fusion=topk_fusion),
        executor=ExecConfig(partitioned=partitioned, partition_rows=128,
                            partition_lookahead=lookahead))
    out = eng.sql(sql)
    rep = eng.last_report
    p = rep.partitions or {}
    return out, {
        "rows_out": out.num_rows,
        "llm_calls": rep.ai_calls,
        "credits": round(rep.ai_credits, 5),
        "model_clock_s": round(model_clock(client), 3),
        "partitions": (f"{p.get('partitions_executed', '-')}/"
                       f"{p.get('partitions_total', '-')}"
                       if p else "-"),
        "cancelled_reqs": p.get("cancelled_requests", 0),
    }


def run(n: int = 2000, seed: int = 0):
    cat = Catalog({"ny_articles": D.nyt_articles(n, seed=seed,
                                                 ai_selectivity=0.30)})

    # -- workload 1: LIMIT-bounded AI_FILTER ---------------------------
    base_out, base = _run(cat, LIMIT_SQL)
    stream_out, stream = _run(cat, LIMIT_SQL, partitioned=True)
    pipe_out, pipe = _run(cat, LIMIT_SQL, pipelined=True,
                          partitioned=True, lookahead=2)
    assert base_out.column("a.id").tolist() == \
        stream_out.column("a.id").tolist(), "streaming changed the rows"
    assert base_out.column("a.id").tolist() == \
        pipe_out.column("a.id").tolist(), "pipelined streaming changed rows"
    call_speedup = base["llm_calls"] / max(stream["llm_calls"], 1)
    credit_speedup = base["credits"] / max(stream["credits"], 1e-12)
    assert call_speedup >= 2.0, \
        f"expected >=2x fewer LLM calls, got {call_speedup:.2f}x"
    assert credit_speedup >= 2.0, \
        f"expected >=2x fewer credits, got {credit_speedup:.2f}x"

    rows = []
    for name, r in (("materialize+truncate", base),
                    ("partitioned", stream),
                    ("partitioned+pipelined", pipe)):
        rows.append({"config": name, **r})
    print(f"\nLIMIT-bounded AI_FILTER over {n} rows (identical rows out):")
    print(fmt_table(rows, ["config", "rows_out", "llm_calls", "credits",
                           "model_clock_s", "partitions", "cancelled_reqs"]))
    print(f"-> {call_speedup:.1f}x fewer LLM calls, "
          f"{credit_speedup:.1f}x fewer credits")

    # -- workload 2: semantic top-k ------------------------------------
    _, full = _run(cat, TOPK_SQL, topk_fusion=False)
    _, fused = _run(cat, TOPK_SQL, topk_fusion=True)
    topk_rows = [{"config": "full-sort+truncate", **full},
                 {"config": "TopK proxy-prefilter", **fused}]
    print(f"\nsemantic ORDER BY ... LIMIT 10 over {n} rows:")
    print(fmt_table(topk_rows, ["config", "rows_out", "llm_calls",
                                "credits", "model_clock_s"]))
    topk_credit_speedup = full["credits"] / max(fused["credits"], 1e-12)
    print(f"-> {topk_credit_speedup:.1f}x fewer credits for top-k")

    payload = {
        "n": n,
        "limit_workload": {"baseline": base, "partitioned": stream,
                           "partitioned_pipelined": pipe,
                           "call_speedup": round(call_speedup, 2),
                           "credit_speedup": round(credit_speedup, 2)},
        "topk_workload": {"full_sort": full, "fused_topk": fused,
                          "credit_speedup": round(topk_credit_speedup, 2)},
    }
    save_result("bench_streaming", payload)
    return payload


def main():
    return run()


if __name__ == "__main__":
    main()
