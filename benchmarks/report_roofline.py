"""Render the dry-run JSON records into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m benchmarks.report_roofline \
        results/dryrun_baseline.json results/dryrun_optimized.json
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def _load(path: str) -> List[dict]:
    with open(path) as f:
        return json.load(f)


def table(recs: List[dict], *, multi_pod: bool) -> str:
    rows = [r for r in recs if r.get("ok") and not r.get("skipped")
            and bool(r.get("multi_pod")) == multi_pod]
    skips = [r for r in recs if r.get("skipped")]
    out = ["| arch | shape | C (ms) | M (ms) | X (ms) | bottleneck | "
           "useful FLOPs | MFU bound | mem/dev (GiB) |",
           "|---|---|---:|---:|---:|---|---:|---:|---:|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        mem = (r.get("memory_per_device") or {})
        total = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.1f} "
            f"| {r['t_memory_s']*1e3:.1f} | {r['t_collective_s']*1e3:.1f} "
            f"| {r['bottleneck']} | {r['useful_flop_ratio']:.1%} "
            f"| {r['mfu_bound']:.2%} | {total/2**30:.1f} |")
    if not multi_pod:
        for r in sorted(skips, key=lambda r: r["arch"]):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['skipped']} | — | — | — |")
    return "\n".join(out)


def summary(recs: List[dict]) -> str:
    ok = [r for r in recs if r.get("ok")]
    comp = [r for r in ok if not r.get("skipped")]
    sp = [r for r in comp if not r.get("multi_pod")]
    mp = [r for r in comp if r.get("multi_pod")]
    return (f"{len(ok)} records OK ({len(sp)} single-pod compiles, "
            f"{len(mp)} multi-pod compiles, "
            f"{len([r for r in ok if r.get('skipped')])} assignment skips)")


def main(argv=None) -> int:
    paths = (argv or sys.argv[1:]) or ["results/dryrun_baseline.json"]
    for path in paths:
        recs = _load(path)
        print(f"\n## {path} — {summary(recs)}\n")
        print("### single-pod 16x16 (256 chips)\n")
        print(table(recs, multi_pod=False))
        print("\n### multi-pod 2x16x16 (512 chips)\n")
        print(table(recs, multi_pod=True))
    return 0


if __name__ == "__main__":
    sys.exit(main())
