"""Decode-backend benchmark: continuous batching vs static batching.

The workload is the serving pattern the paper's §2 platform actually
faces: a burst of AI_COMPLETE generations of wildly mixed lengths (a few
long tails among many short answers) followed by a queue of short
AI_FILTER scores.  Static batching drains each batch to its longest
member and only then starts the filters; the continuous backend retires
finished sequences every step, back-fills the freed slots, and chunk-
prefills incoming prompts between decode steps.

Gates (``--check``, on by default):
  * result rows byte-identical between the two backends;
  * total credits conserved (identical per-request metering);
  * >= 2x tokens/sec and lower p95 latency for continuous batching.

The results JSON includes the backend telemetry (step counts, slot
occupancy, KV-block peaks) and the roofline-derived utilization of the
prefill/decode step functions per workload mix (``launch/roofline.py``).
"""
from __future__ import annotations

import argparse
import copy
import sys
import time
from typing import Any, Dict, List, Tuple

import numpy as np

from benchmarks.common import fmt_table, save_result
from repro.inference.backend import COMPLETE, SCORE, Request, Result
from repro.inference.engine import JaxInferenceEngine

ARCH = "proxy-8b"


def _mixed_workload(n_complete: int = 32, n_score: int = 16,
                    long_every: int = 8, long_tokens: int = 96,
                    short_tokens: int = 4) -> List[Request]:
    """Short completions with a long tail every ``long_every`` requests
    (so every static chunk drains to the long one), then short filters
    queued behind all of them."""
    reqs: List[Request] = []
    rid = 0
    for i in range(n_complete):
        rid += 1
        mt = long_tokens if i % long_every == 0 else short_tokens
        reqs.append(Request(
            f"summarize support ticket {i}: the product arrived late and",
            ARCH, COMPLETE, max_tokens=mt, request_id=rid))
    for i in range(n_score):
        rid += 1
        reqs.append(Request(
            f"is review {i} about shipping delays and refunds?",
            ARCH, SCORE, request_id=rid))
    return reqs


def _prefill_heavy_workload(n: int = 24) -> List[Request]:
    """Long prompts, single-pass scores plus tiny completions — the step
    mix is dominated by chunked prefill."""
    body = ("the customer writes a long and detailed account of the "
            "delivery problem, the packaging damage and the support calls "
            "that followed, asking for a refund. ")
    reqs: List[Request] = []
    for i in range(n):
        kind = SCORE if i % 3 else COMPLETE
        reqs.append(Request(
            f"[case {i}] {body} is this case about shipping?", ARCH, kind,
            max_tokens=4, request_id=i + 1))
    return reqs


def _row_key(r: Result) -> Tuple:
    return (r.request_id, r.kind, r.text, r.score, r.tokens_in,
            r.tokens_out, r.credits)


def _serve(engine: JaxInferenceEngine, reqs: List[Request]
           ) -> Tuple[float, List[Result]]:
    batch = [copy.deepcopy(r) for r in reqs]
    t0 = time.perf_counter()
    out = engine.submit_batch(batch)
    return time.perf_counter() - t0, out


def _measure(engine: JaxInferenceEngine, reqs: List[Request],
             repeats: int = 3) -> Dict[str, Any]:
    _serve(engine, reqs)                      # warm every jit key
    dt, out = min((_serve(engine, reqs) for _ in range(repeats)),
                  key=lambda p: p[0])         # best-of-N rides out load spikes
    toks = sum(r.tokens_in + r.tokens_out for r in out)
    lat = np.asarray([r.latency_s for r in out])
    return {
        "wall_s": dt, "tokens": toks, "tokens_per_s": toks / dt,
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p95_ms": float(np.percentile(lat, 95) * 1e3),
        "credits": sum(r.credits for r in out),
        "rows": [_row_key(r) for r in out],
        "backend": engine.backend_stats(),
    }


def run(check: bool = True, quick: bool = False) -> Dict[str, Any]:
    mixes = {
        "decode_heavy": _mixed_workload(
            n_complete=24 if quick else 32, n_score=8 if quick else 16),
        "prefill_heavy": _prefill_heavy_workload(12 if quick else 24),
    }
    results: Dict[str, Any] = {}
    table = []
    for mix_name, reqs in mixes.items():
        static = JaxInferenceEngine(ARCH, smoke=True, max_seq=192,
                                    backend="static", seed=0)
        cont = JaxInferenceEngine(ARCH, smoke=True, max_seq=192,
                                  backend="continuous", seed=0)
        ms = _measure(static, reqs)
        mc = _measure(cont, reqs)
        identical = ms["rows"] == mc["rows"]
        speedup = mc["tokens_per_s"] / ms["tokens_per_s"]
        roofline = cont.backend_roofline()
        steps = {k: roofline[k] for k in roofline}
        bs = mc["backend"]
        n_steps = bs["prefill_steps"] + bs["decode_steps"]
        util = 0.0
        if n_steps and roofline:
            util = sum(
                roofline[k]["mfu_bound"] * bs[f"{k}_steps"]
                for k in ("prefill", "decode") if k in roofline) / n_steps
        results[mix_name] = {
            "requests": len(reqs),
            "static": {k: v for k, v in ms.items() if k != "rows"},
            "continuous": {k: v for k, v in mc.items() if k != "rows"},
            "rows_identical": identical,
            "credits_conserved": ms["credits"] == mc["credits"],
            "tokens_per_s_speedup": speedup,
            "p95_ratio": mc["p95_ms"] / ms["p95_ms"],
            "roofline_utilization_per_step_mix": {
                "step_mix": {"prefill_steps": bs["prefill_steps"],
                             "decode_steps": bs["decode_steps"],
                             "decode_slot_occupancy":
                                 bs["decode_slot_occupancy"]},
                "mix_weighted_mfu_bound": util,
                "per_step_kind": steps,
            },
        }
        for name, m in (("static", ms), ("continuous", mc)):
            table.append({
                "mix": mix_name, "backend": name,
                "tok/s": round(m["tokens_per_s"], 1),
                "p50_ms": round(m["p50_ms"], 1),
                "p95_ms": round(m["p95_ms"], 1),
                "identical": identical,
                "util%": (round(100 * util, 2)
                          if name == "continuous" else ""),
            })
        if check:
            assert identical, f"{mix_name}: result rows differ"
            assert ms["credits"] == mc["credits"], \
                f"{mix_name}: credits not conserved"
        if check and mix_name == "decode_heavy":
            assert speedup >= 2.0, \
                f"{mix_name}: continuous speedup {speedup:.2f}x < 2x"
            assert mc["p95_ms"] < ms["p95_ms"], \
                f"{mix_name}: continuous p95 not lower"
    print(fmt_table(table, ["mix", "backend", "tok/s", "p50_ms", "p95_ms",
                            "identical", "util%"]))
    path = save_result("bench_backend", results)
    print(f"saved {path}")
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload (CI smoke)")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the speedup/identity gates")
    args = ap.parse_args(argv)
    run(check=not args.no_check, quick=args.quick)
    return 0


if __name__ == "__main__":
    sys.exit(main())
