"""Learned cost model v2: kNN prior transfer + plan memo gates.

**Transfer workload.**  A stream of queries over
`repro.data.datasets.skewed_articles`, each combining a *broad*
headline predicate (true selectivity ~0.95) with a *narrow* summary
predicate (~0.05) — but every query phrases both predicates with a
**fresh paraphrase**, so their fingerprints are unseen on every single
query.  The table is deliberately smaller than
`ExecConfig.min_rows_for_pilot`: this is the regime where pilot
sampling cannot pay for itself, so a cold-start engine has *nothing*
to plan with and evaluates the (statically cheaper-looking) broad
predicate first on every query.  The transfer engine shares the store
and semantic index of a trained engine: each unseen paraphrase embeds
next to an observed donor, borrows its selectivity/cost prior
(`est_source == "transferred"`), and the optimizer orders narrow-first
at compile time.

Gates (identical result rows required):

  * LLM calls:  cold / transfer >= 1.3
  * credits:    cold / transfer >= 1.3

**Plan memo.**  One query repeated three times on a fresh engine: run 1
optimizes for real (cost races > 0), run 2 re-optimizes (the stats
moved off the cold defaults: drift), run 3 must be a memo hit with
**zero** optimizer cost races.

Artifacts -> results/bench_learned.json.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import fmt_table, model_clock, save_result
from repro.core import (AisqlEngine, Catalog, CostDefaults, ExecConfig,
                        OptimizerConfig, StatsStore)
from repro.data import datasets as D
from repro.inference.api import make_simulated_client
from repro.semindex import SemanticIndexManager, SemIndexConfig

# Paraphrase families.  Ground truth in skewed_articles is column-scoped
# (`_truth__headline` ~0.95, `_truth__summary` ~0.05), so paraphrases
# over the same column are the *same* predicate with a different prompt:
# identical result rows, distinct fingerprints.  Within a family the
# templates share content words (word-bag embeddings land close); across
# families the vocabularies are disjoint.
BROAD_TRAIN = [
    "is this headline about newsworthy current events? {0}",
    "does this headline cover newsworthy current events? {0}",
]
NARROW_TRAIN = [
    "does this summary cover database systems research in depth? {0}",
    "is this summary about in-depth database systems research? {0}",
]
BROAD_PARAPHRASES = [
    "would an editor call this headline newsworthy current events? {0}",
    "is the headline here reporting newsworthy current events? {0}",
    "do current events make this headline newsworthy? {0}",
    "is this a newsworthy current events headline? {0}",
    "does the headline concern newsworthy current events? {0}",
    "newsworthy current events in this headline? {0}",
]
NARROW_PARAPHRASES = [
    "is this summary in-depth database systems research? {0}",
    "does the summary treat database systems research in depth? {0}",
    "in-depth research on database systems in this summary? {0}",
    "is the summary an in-depth database systems research piece? {0}",
    "does this summary go in depth on database systems research? {0}",
    "summary covering database systems research in depth? {0}",
]

MEMO_SQL = ("SELECT * FROM articles AS a WHERE "
            "AI_FILTER(PROMPT('broad? {0}', a.headline)) AND "
            "AI_FILTER(PROMPT('does this text concern database "
            "research? {0}', a.summary))")


def _sql(broad: str, narrow: str) -> str:
    return ("SELECT * FROM articles AS a WHERE "
            f"AI_FILTER(PROMPT('{broad}', a.headline)) AND "
            f"AI_FILTER(PROMPT('{narrow}', a.summary))")


def _engine(n, client, *, store, semindex=None, seed=0):
    defaults = dataclasses.replace(CostDefaults(), transfer_min_sim=0.25)
    return AisqlEngine(
        Catalog({"articles": D.skewed_articles(n, seed=seed)}),
        client,
        optimizer=OptimizerConfig(cost_defaults=defaults),
        stats=store, semindex=semindex)


def run_transfer(n: int = 160, queries: int = 6, seed: int = 0):
    """Cold-start vs kNN-transfer engine on paraphrased-unseen queries."""
    workload = [_sql(BROAD_PARAPHRASES[i], NARROW_PARAPHRASES[i])
                for i in range(queries)]

    # -- train: observe the donor predicates once -----------------------
    store = StatsStore()
    semindex = SemanticIndexManager(SemIndexConfig(impl="reference"))
    trainer = _engine(n, make_simulated_client(pipelined=True),
                      store=store, semindex=semindex, seed=seed)
    for b, nr in zip(BROAD_TRAIN, NARROW_TRAIN):
        trainer.sql(_sql(b, nr))

    def replay(name, store, semindex):
        client = make_simulated_client(pipelined=True)
        eng = _engine(n, client, store=store, semindex=semindex, seed=seed)
        ids, transferred, calls, credits = [], 0, 0, 0.0
        for sql in workload:
            ids.append(sorted(eng.sql(sql).column("a.id").tolist()))
            rep = eng.last_report
            calls += rep.ai_calls
            credits += rep.ai_credits
            transferred += sum(op.est_source == "transferred"
                               for op in rep.operators)
        return {"config": name, "rows_out": sum(len(i) for i in ids),
                "llm_calls": calls, "credits": round(credits, 5),
                "model_clock_s": round(model_clock(client), 3),
                "transferred_ops": transferred}, ids

    cold, cold_ids = replay("cold-start", StatsStore(), None)
    warm, warm_ids = replay("knn-transfer", store, semindex)

    identical = cold_ids == warm_ids
    calls_x = cold["llm_calls"] / max(warm["llm_calls"], 1)
    credits_x = cold["credits"] / max(warm["credits"], 1e-12)
    for r, x_calls, x_cred in ((cold, 1.0, 1.0),
                               (warm, calls_x, credits_x)):
        r["speedup_calls"] = round(x_calls, 2)
        r["speedup_credits"] = round(x_cred, 2)
    return [cold, warm], identical, calls_x, credits_x


def run_memo(n: int = 300, repeats: int = 3, seed: int = 0):
    """Same query repeated: the final run must be a zero-race memo hit."""
    eng = AisqlEngine(
        Catalog({"articles": D.skewed_articles(n, seed=seed)}),
        make_simulated_client(pipelined=True),
        executor=ExecConfig(pilot_rows=0))
    rows = []
    for i in range(repeats):
        eng.sql(MEMO_SQL)
        memo = dict(eng.last_report.memo)
        rows.append({"run": i + 1, **memo})
    return rows


def main():
    rows, identical, calls_x, credits_x = run_transfer()
    print("== kNN prior transfer vs cold start "
          "(paraphrased-but-unseen predicates, pilot-free regime) ==")
    print(fmt_table(rows, ["config", "rows_out", "llm_calls", "credits",
                           "model_clock_s", "transferred_ops",
                           "speedup_calls", "speedup_credits"]))
    print(f"identical result rows across engines: {identical}")
    assert identical, "transferred priors must not change the result set"
    assert rows[1]["transferred_ops"] > 0, \
        "transfer engine never used a transferred prior"
    assert calls_x >= 1.3, \
        f"transfer must save >=1.3x LLM calls (got {calls_x:.2f}x)"
    assert credits_x >= 1.3, \
        f"transfer must save >=1.3x credits (got {credits_x:.2f}x)"

    memo_rows = run_memo()
    print("\n== plan memo (one query repeated) ==")
    print(fmt_table(memo_rows, ["run", "hit", "cost_races", "entries"]))
    final = memo_rows[-1]
    assert final["hit"], "final repeat must hit the plan memo"
    assert final["cost_races"] == 0, \
        f"memo hit ran {final['cost_races']} cost races (expected 0)"
    assert memo_rows[0]["cost_races"] > 0, \
        "first run should have optimized for real"

    save_result("bench_learned", {
        "transfer": {"rows": rows, "identical_rows": identical,
                     "speedup_calls": round(calls_x, 3),
                     "speedup_credits": round(credits_x, 3)},
        "memo": memo_rows,
    })
    return rows, memo_rows


if __name__ == "__main__":
    main()
