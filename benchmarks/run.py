"""Run every paper-reproduction benchmark (one per table/figure).

    PYTHONPATH=src python -m benchmarks.run [--skip-serving]

Artifacts land in results/*.json; the printed tables mirror the paper's
Figures 9-12 and Tables 2-4 plus the §5.4 aggregation optimization and a
§2 serving-throughput check on the real JAX engine.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-serving", action="store_true",
                    help="skip the real-engine serving benchmark (slow)")
    args = ap.parse_args(argv)

    from benchmarks import (bench_adaptive, bench_agg_shortcircuit,
                            bench_cascade, bench_concurrent,
                            bench_hybrid_join, bench_index,
                            bench_join_placement, bench_join_rewrite,
                            bench_learned, bench_predicate_reorder,
                            bench_streaming)
    benches = [
        ("Fig 9 predicate reordering", bench_predicate_reorder.main),
        ("adaptive re-optimization (learned stats)", bench_adaptive.main),
        ("learned cost model v2 (kNN transfer + plan memo)",
         bench_learned.main),
        ("streaming partition-parallel LIMIT + top-k", bench_streaming.main),
        ("semantic index: join blocking + kernel gate", bench_index.main),
        ("concurrent multi-tenant serving", bench_concurrent.main),
        ("Fig 10 join placement", bench_join_placement.main),
        ("Table 2 / Fig 11 cascades", bench_cascade.main),
        ("Tables 3-4 / Fig 12 join rewrite", bench_join_rewrite.main),
        ("S5.4 agg short-circuit", bench_agg_shortcircuit.main),
        ("beyond-paper: hybrid k-pass join", bench_hybrid_join.main),
    ]
    if not args.skip_serving:
        from benchmarks import bench_backend, bench_serving
        benches.append(("S2 serving throughput", bench_serving.main))
        benches.append(("S2 decode backend: continuous vs static batching",
                        lambda: bench_backend.main(["--quick"])))

    t0 = time.perf_counter()
    for name, fn in benches:
        print(f"\n######## {name} ########")
        fn()
    print(f"\nall benchmarks done in {time.perf_counter() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
