"""Run every paper-reproduction benchmark (one per table/figure).

    PYTHONPATH=src python -m benchmarks.run [--skip-serving]

Artifacts land in results/*.json; the printed tables mirror the paper's
Figures 9-12 and Tables 2-4 plus the §5.4 aggregation optimization and a
§2 serving-throughput check on the real JAX engine.  After a full run
the per-bench headline metrics are folded into `BENCH_trajectory.json`
at the repo root, keyed by git SHA, so the perf trajectory across PRs
stays inspectable.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRAJECTORY = os.path.join(REPO_ROOT, "BENCH_trajectory.json")


def _git_sha() -> str:
    try:
        proc = subprocess.run(["git", "rev-parse", "HEAD"],
                              capture_output=True, text=True,
                              cwd=REPO_ROOT, timeout=10)
        sha = proc.stdout.strip()
        return sha if proc.returncode == 0 and sha else "unknown"
    except OSError:
        return "unknown"


def _headline(payload) -> dict:
    """Scalar top-level fields only — the trajectory tracks headline
    numbers, not full artifacts (those stay in results/*.json)."""
    return {k: v for k, v in payload.items()
            if isinstance(v, (int, float, str, bool)) or v is None}


def update_trajectory() -> str:
    """Fold every results/*.json headline into BENCH_trajectory.json,
    keyed by the current git SHA (re-running on the same SHA replaces
    that SHA's entry instead of appending a duplicate)."""
    from benchmarks.common import RESULTS_DIR
    benches = {}
    if os.path.isdir(RESULTS_DIR):
        for fname in sorted(os.listdir(RESULTS_DIR)):
            if not fname.endswith(".json"):
                continue
            try:
                with open(os.path.join(RESULTS_DIR, fname)) as f:
                    payload = json.load(f)
            except (OSError, ValueError):
                continue
            if isinstance(payload, dict):
                benches[fname[:-len(".json")]] = _headline(payload)
    sha = _git_sha()
    entries = []
    if os.path.exists(TRAJECTORY):
        try:
            with open(TRAJECTORY) as f:
                entries = json.load(f).get("entries", [])
        except (OSError, ValueError):
            entries = []
    entries = [e for e in entries if e.get("sha") != sha]
    entries.append({"sha": sha, "recorded_at": time.strftime(
        "%Y-%m-%dT%H:%M:%SZ", time.gmtime()), "benches": benches})
    with open(TRAJECTORY, "w") as f:
        json.dump({"entries": entries}, f, indent=1)
        f.write("\n")
    return TRAJECTORY


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-serving", action="store_true",
                    help="skip the real-engine serving benchmark (slow)")
    args = ap.parse_args(argv)

    from benchmarks import (bench_adaptive, bench_agg_shortcircuit,
                            bench_cascade, bench_concurrent, bench_http,
                            bench_hybrid_join, bench_index,
                            bench_join_placement, bench_join_rewrite,
                            bench_learned, bench_predicate_reorder,
                            bench_streaming)
    benches = [
        ("Fig 9 predicate reordering", bench_predicate_reorder.main),
        ("adaptive re-optimization (learned stats)", bench_adaptive.main),
        ("learned cost model v2 (kNN transfer + plan memo)",
         bench_learned.main),
        ("streaming partition-parallel LIMIT + top-k", bench_streaming.main),
        ("semantic index: join blocking + kernel gate", bench_index.main),
        ("concurrent multi-tenant serving", bench_concurrent.main),
        ("Fig 10 join placement", bench_join_placement.main),
        ("Table 2 / Fig 11 cascades", bench_cascade.main),
        ("Tables 3-4 / Fig 12 join rewrite", bench_join_rewrite.main),
        ("S5.4 agg short-circuit", bench_agg_shortcircuit.main),
        ("beyond-paper: hybrid k-pass join", bench_hybrid_join.main),
        ("HTTP serving front-end + NL2SQL",
         lambda: bench_http.main(["--quick"])),
    ]
    if not args.skip_serving:
        from benchmarks import bench_backend, bench_obs, bench_serving
        benches.append(("S2 serving throughput", bench_serving.main))
        benches.append(("S2 decode backend: continuous vs static batching",
                        lambda: bench_backend.main(["--quick"])))
        benches.append(("observability: overhead + billing reconciliation",
                        lambda: bench_obs.main(["--quick"])))

    t0 = time.perf_counter()
    for name, fn in benches:
        print(f"\n######## {name} ########")
        fn()
    path = update_trajectory()
    print(f"\nall benchmarks done in {time.perf_counter() - t0:.1f}s "
          f"(trajectory -> {os.path.relpath(path, REPO_ROOT)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
