"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def model_clock(client) -> float:
    """Total modelled serving seconds across the client's backends."""
    total = 0.0
    seen = set()
    for reps in client.scheduler._replicas.values():
        for r in reps:
            if id(r) not in seen and hasattr(r, "clock_s"):
                total += r.clock_s
                seen.add(id(r))
    return total


def save_result(name: str, payload: Dict[str, Any]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def fmt_table(rows: List[Dict[str, Any]], cols: List[str]) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    head = "  ".join(c.ljust(widths[c]) for c in cols)
    lines = [head, "-" * len(head)]
    for r in rows:
        lines.append("  ".join(str(r.get(c, "")).ljust(widths[c])
                               for c in cols))
    return "\n".join(lines)
