"""Fig 9: predicate reordering — IN-selectivity sweep 0.1..1.0.

Query shape (paper §6.1): WHERE category IN (...) AND AI_FILTER(...).
Speedup = time with the AI predicate evaluated FIRST (unoptimized SQL
order) / time with it evaluated LAST (cost-ranked order).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table, model_clock, save_result
from repro.core import AisqlEngine, Catalog, ExecConfig, OptimizerConfig
from repro.data import datasets as D
from repro.inference.api import make_simulated_client


def run(rows: int = 1000, seed: int = 0):
    out = []
    for k in (1, 2, 3, 5, 7, 10):
        sel = k / 10
        t = D.nyt_articles(rows, seed=seed)
        cat = Catalog({"articles": t})
        cats = ",".join(f"'{c}'" for c in D.NYT_CATEGORIES[:k])
        sql = (f"SELECT * FROM articles AS a WHERE "
               "AI_FILTER(PROMPT('discusses databases? {0}', a.body)) AND "
               f"a.category IN ({cats})")
        clocks = {}
        calls = {}
        for mode in ("none", "ai_aware"):
            client = make_simulated_client()
            eng = AisqlEngine(cat, client,
                              optimizer=OptimizerConfig(mode=mode),
                              executor=ExecConfig(adaptive_reorder=False))
            eng.sql(sql)
            clocks[mode] = model_clock(client)
            calls[mode] = eng.last_report.ai_calls
        out.append({"in_selectivity": sel,
                    "t_unordered_s": round(clocks["none"], 3),
                    "t_reordered_s": round(clocks["ai_aware"], 3),
                    "llm_calls_unordered": calls["none"],
                    "llm_calls_reordered": calls["ai_aware"],
                    "speedup": round(clocks["none"] / clocks["ai_aware"], 2)})
    return out


def main():
    rows = run()
    print("== Fig 9: predicate reordering (AI_FILTER last) ==")
    print(fmt_table(rows, ["in_selectivity", "llm_calls_unordered",
                           "llm_calls_reordered", "speedup"]))
    save_result("bench_predicate_reorder", {"rows": rows})
    return rows


if __name__ == "__main__":
    main()
