"""Adaptive runtime re-optimization vs the static plan.

Workload: two AI_FILTER predicates over `repro.data.datasets.
skewed_articles` — statically indistinguishable (same model, same column
lengths, near-identical template lengths) but with true selectivities of
~0.95 (broad, written first) and ~0.05 (narrow).  The static planner's
0.5-default keeps the written order, paying the broad predicate on every
row; the adaptive runtime pilots a small sample, learns the skew, and
evaluates the narrow predicate first.

Three configurations, identical result rows required:

  * **static**    — pilot off, adaptive reorder off (the seed planner);
  * **adaptive (cold)** — pilot sampling on, empty `StatsStore`;
  * **adaptive (warm)** — a second engine sharing the store persisted by
    the cold run: no pilot needed, the plan is re-ordered at compile
    time from observed stats (the cross-query feedback loop).

Reported: LLM calls, credits, modelled serving seconds, and the
estimated-vs-actual selectivity error (mean |est - act|) from
`QueryReport.operators`.  Artifacts -> results/bench_adaptive.json.
"""
from __future__ import annotations

import os

from benchmarks.common import RESULTS_DIR, fmt_table, model_clock, save_result
from repro.core import AisqlEngine, Catalog, ExecConfig, OptimizerConfig
from repro.core.stats import StatsStore
from repro.data import datasets as D
from repro.inference.api import make_simulated_client

# The broad predicate's template is the shorter one, so the static cost
# model (token-length × price, selectivity 0.5 for both) confidently ranks
# it FIRST — the worst order: it passes ~95% of rows, so the narrow
# predicate still runs on nearly the full table.
SQL = ("SELECT * FROM articles AS a WHERE "
       "AI_FILTER(PROMPT('newsworthy? {0}', a.headline)) AND "
       "AI_FILTER(PROMPT('does this summary cover database systems "
       "research in depth? {0}', a.summary))")


def _run(n: int, *, pilot: bool, store: StatsStore, seed: int = 0):
    cat = Catalog({"articles": D.skewed_articles(n, seed=seed)})
    client = make_simulated_client(pipelined=True)
    exec_cfg = ExecConfig(adaptive_reorder=pilot,
                          pilot_rows=48 if pilot else 0)
    eng = AisqlEngine(cat, client, optimizer=OptimizerConfig(),
                      executor=exec_cfg, stats=store)
    out = eng.sql(SQL)
    rep = eng.last_report
    sel_err = [abs(op.est_selectivity - op.actual_selectivity)
               for op in rep.operators if op.actual_selectivity is not None]
    return {
        "rows_out": out.num_rows,
        "llm_calls": rep.ai_calls,
        "credits": round(rep.ai_credits, 5),
        "model_clock_s": round(model_clock(client), 3),
        "mean_sel_error": round(sum(sel_err) / max(len(sel_err), 1), 3),
        "reoptimized": bool(rep.reoptimizations),
        "pilot_rows": (rep.pilot or {}).get("sampled_rows", 0),
    }


def run(n: int = 2000, seed: int = 0):
    stats_path = os.path.join(RESULTS_DIR, "adaptive_stats.json")
    if os.path.exists(stats_path):
        os.remove(stats_path)

    static = _run(n, pilot=False, store=StatsStore(), seed=seed)

    cold_store = StatsStore(stats_path)
    cold = _run(n, pilot=True, store=cold_store, seed=seed)
    cold_store.save()

    warm = _run(n, pilot=True, store=StatsStore(stats_path), seed=seed)

    rows = []
    for name, r in (("static", static), ("adaptive-cold", cold),
                    ("adaptive-warm", warm)):
        rows.append({"config": name, **r,
                     "speedup_calls": round(static["llm_calls"]
                                            / max(r["llm_calls"], 1), 2),
                     "speedup_credits": round(static["credits"]
                                              / max(r["credits"], 1e-12), 2)})
    identical = len({r["rows_out"] for r in rows}) == 1
    return rows, identical


def main():
    rows, identical = run()
    print("== adaptive re-optimization vs static plan "
          "(skewed selectivity) ==")
    print(fmt_table(rows, ["config", "rows_out", "llm_calls", "credits",
                           "mean_sel_error", "pilot_rows", "reoptimized",
                           "speedup_calls", "speedup_credits"]))
    print(f"identical result rows across configs: {identical}")
    assert identical, "adaptive plans must not change the result set"
    adaptive = [r for r in rows if r["config"] != "static"]
    assert all(r["llm_calls"] < rows[0]["llm_calls"] for r in adaptive), \
        "adaptive must reduce LLM calls on the skewed workload"
    save_result("bench_adaptive", {"rows": rows,
                                   "identical_rows": identical})
    return rows


if __name__ == "__main__":
    main()
