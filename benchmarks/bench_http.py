"""HTTP serving front-end: sustained QPS, streaming latency, fidelity.

Boots the real `AisqlHttpServer` over a `ServingEngine` on a loopback
socket and drives it with concurrent stdlib clients.  Four gated
claims:

  * **throughput**: >= 200 QPS of mixed cached/uncached AISQL over the
    wire (multi-tenant, bearer-token auth on every request);
  * **streaming**: first-row p95 over chunked NDJSON < buffered
    full-result p95 on cold AI queries (the partition-incremental
    stream pays off before the query finishes);
  * **fidelity**: rows received over HTTP (buffered *and* streamed)
    byte-identical to direct `ServingEngine` execution on an
    identically-seeded engine;
  * **accounting**: per-tenant billing conserved — tenant meters sum
    to the pipeline's dispatch spend and the backends' own meters;
  * **NL->AISQL**: >= 90% of the seeded question corpus compiles to a
    validated query whose rows match the grounded-truth verified
    query.

    PYTHONPATH=src python -m benchmarks.bench_http [--quick]
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import fmt_table, save_result
from repro.core import Catalog, ExecConfig, ServingConfig, ServingEngine
from repro.core.serving import TenantPolicy
from repro.data import datasets as D
from repro.inference.api import make_simulated_client
from repro.serve import (AisqlHttpClient, AisqlHttpServer, HttpConfig,
                         NL2SQLOperator, SemanticModel, VerifiedQuery,
                         question_corpus)
from repro.serve.http import table_rows

SEED = 0
TENANTS = ["alpha", "beta", "gamma", "delta"]


def make_catalog(rows: int) -> Catalog:
    return Catalog({
        "articles": D.skewed_articles(rows, seed=3),
        "reviews": D.cascade_table("IMDB", rows=min(rows, 400), seed=1),
    })


def make_engine(rows: int, workers: int = 8) -> ServingEngine:
    return ServingEngine.simulated(
        make_catalog(rows), seed=SEED,
        tenants={t: TenantPolicy() for t in TENANTS},
        cfg=ServingConfig(
            workers=workers,
            executor=ExecConfig(partitioned=True, partition_rows=64)))


def make_model(catalog: Catalog) -> SemanticModel:
    model = SemanticModel.from_catalog(catalog)
    model.verified = [
        VerifiedQuery("small_ids", "list article ids below forty",
                      "SELECT a.id FROM articles a WHERE a.id < 40"),
        VerifiedQuery("count_articles", "count all the articles",
                      "SELECT COUNT(*) FROM articles"),
        VerifiedQuery("broad", "which articles cover a broad topic",
                      "SELECT a.id FROM articles a WHERE "
                      "AI_FILTER(PROMPT('broad topic? {0}', a.headline))"),
        VerifiedQuery("review_ids", "list review ids below thirty",
                      "SELECT r.id FROM reviews r WHERE r.id < 30"),
    ]
    return model


# -- the mixed wire workload (cached + uncached, relational + AI).
# The AI queries carry a LIMIT so partitioned early termination bounds
# their per-request row count; after warmup their predicate answers are
# cross-query cache hits (the "cached" half of the mix).
MIXED = [
    "SELECT a.id FROM articles a WHERE a.id < 50",
    "SELECT COUNT(*) FROM articles",
    "SELECT a.id FROM articles a WHERE "
    "AI_FILTER(PROMPT('broad topic? {0}', a.headline)) LIMIT 20",
    "SELECT r.id FROM reviews r WHERE r.id < 60",
    "SELECT a.id, a.headline FROM articles a WHERE a.id < 25 LIMIT 10",
]


def phase_throughput(srv: AisqlHttpServer, n_queries: int,
                     threads_per_tenant: int = 2) -> Dict[str, float]:
    """Mixed cached/uncached workload over the wire; returns QPS."""
    # warm the pipeline cache so the AI query is a cross-query hit
    warm = AisqlHttpClient(srv.host, srv.port, token="tok-alpha")
    for sql in MIXED:
        warm.query(sql)
    counter = {"done": 0, "errors": 0}
    lock = threading.Lock()
    per_thread = max(n_queries // (len(TENANTS) * threads_per_tenant), 1)

    def drive(tenant: str, salt: int) -> None:
        client = AisqlHttpClient(srv.host, srv.port,
                                 token=f"tok-{tenant}")
        for i in range(per_thread):
            sql = MIXED[(i + salt) % len(MIXED)]
            try:
                client.query(sql)
                with lock:
                    counter["done"] += 1
            except Exception:
                with lock:
                    counter["errors"] += 1

    workers = [threading.Thread(target=drive, args=(t, j))
               for t in TENANTS for j in range(threads_per_tenant)]
    t0 = time.perf_counter()
    for w in workers:
        w.start()
    for w in workers:
        w.join()
    wall = time.perf_counter() - t0
    assert counter["errors"] == 0, f"{counter['errors']} wire errors"
    return {"queries": counter["done"], "wall_s": wall,
            "qps": counter["done"] / wall}


def phase_streaming(srv: AisqlHttpServer, trials: int) -> Dict[str, float]:
    """Cold AI queries: time-to-first-row (streamed) vs full-result
    latency (buffered).  Each trial uses fresh prompt text so both
    paths pay the uncached cost; prompts are symmetric between arms."""
    client = AisqlHttpClient(srv.host, srv.port, token="tok-alpha",
                             timeout=120.0)
    first_row, full = [], []
    for i in range(trials):
        sql_s = ("SELECT a.id FROM articles a WHERE AI_FILTER("
                 f"PROMPT('cold stream probe {i}: {{0}}', a.headline))")
        sql_b = ("SELECT a.id FROM articles a WHERE AI_FILTER("
                 f"PROMPT('cold buffer probe {i}: {{0}}', a.headline))")
        t0 = time.perf_counter()
        saw_first = None
        for event in client.query_stream(sql_s):
            if event["kind"] == "row" and saw_first is None:
                saw_first = time.perf_counter() - t0
        first_row.append(saw_first if saw_first is not None
                         else time.perf_counter() - t0)
        t0 = time.perf_counter()
        client.query(sql_b)
        full.append(time.perf_counter() - t0)

    def p95(xs: List[float]) -> float:
        return sorted(xs)[min(int(0.95 * len(xs)), len(xs) - 1)]

    return {"trials": trials,
            "stream_first_row_p95_s": p95(first_row),
            "buffered_full_p95_s": p95(full),
            "stream_first_row_p50_s": sorted(first_row)[len(first_row) // 2],
            "buffered_full_p50_s": sorted(full)[len(full) // 2]}


def phase_fidelity(srv: AisqlHttpServer, rows: int) -> int:
    """Buffered and streamed HTTP rows byte-identical to direct
    `ServingEngine` execution on an identically-seeded engine."""
    client = AisqlHttpClient(srv.host, srv.port, token="tok-alpha")
    checked = 0
    with make_engine(rows) as ref:
        for sql in MIXED:
            direct = ref.submit("alpha", sql).result(timeout=60.0)
            want = json.dumps(table_rows(direct)[1]).encode()
            got_b = json.dumps(client.query(sql)["rows"]).encode()
            got_s = json.dumps(
                [e["values"] for e in client.query_stream(sql)
                 if e["kind"] == "row"]).encode()
            assert got_b == want, f"buffered rows diverged: {sql}"
            assert got_s == want, f"streamed rows diverged: {sql}"
            checked += 1
    return checked


def phase_nl2sql(srv: AisqlHttpServer, engine: ServingEngine,
                 model: SemanticModel, n: int) -> Dict[str, float]:
    """Compile the seeded corpus over the wire; a question counts only
    if it compiles AND returns the grounded-truth rows."""
    client = AisqlHttpClient(srv.host, srv.port, token="tok-beta")
    ok = 0
    corpus = question_corpus(model, n, seed=2)
    for question, truth in corpus:
        try:
            out = client.nl2sql(question, execute=True)
        except Exception:
            continue
        want = engine.submit("beta", truth.sql).result(timeout=60.0)
        if json.dumps(out["rows"]).encode() == \
                json.dumps(table_rows(want)[1]).encode():
            ok += 1
    return {"questions": n, "compiled_and_grounded": ok,
            "success_rate": ok / n}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload (CI smoke)")
    args = ap.parse_args(argv)
    rows = 400 if args.quick else 1200
    n_queries = 240 if args.quick else 800
    trials = 6 if args.quick else 12
    n_questions = 20 if args.quick else 40

    engine = make_engine(rows)
    model = make_model(engine.catalog)
    nl2sql = NL2SQLOperator(model, engine.catalog,
                            make_simulated_client(seed=SEED + 9),
                            max_attempts=3)
    cfg = HttpConfig(tokens={f"tok-{t}": t for t in TENANTS})
    with engine, AisqlHttpServer(engine, nl2sql=nl2sql, cfg=cfg) as srv:
        tput = phase_throughput(srv, n_queries)
        stream = phase_streaming(srv, trials)
        fidelity_checked = phase_fidelity(srv, rows)
        nl = phase_nl2sql(srv, engine, model, n_questions)
        engine.drain()
        rep = engine.report()

    # billing conservation across every wire request
    tenant_sum = sum(t.credits_spent for t in rep.tenants.values())
    assert abs(tenant_sum - rep.total_credits) < 1e-6, \
        "tenant meters do not sum to the dispatch spend"
    if rep.backend_credits is not None:
        assert abs(rep.total_credits - rep.backend_credits) < 1e-6, \
            "dispatch spend does not match the backends' own meters"

    print("== HTTP serving front-end ==")
    print(fmt_table([
        {"phase": "throughput", "metric": "QPS",
         "value": f"{tput['qps']:.0f}",
         "detail": f"{tput['queries']} queries in "
                   f"{tput['wall_s']:.2f}s (4 tenants, auth on)"},
        {"phase": "streaming", "metric": "first-row p95",
         "value": f"{stream['stream_first_row_p95_s'] * 1e3:.1f}ms",
         "detail": f"buffered full p95 "
                   f"{stream['buffered_full_p95_s'] * 1e3:.1f}ms"},
        {"phase": "fidelity", "metric": "queries byte-identical",
         "value": str(fidelity_checked), "detail": "buffered + streamed"},
        {"phase": "nl2sql", "metric": "grounded success",
         "value": f"{nl['success_rate'] * 100:.0f}%",
         "detail": f"{nl['compiled_and_grounded']}/{nl['questions']} "
                   f"questions"},
    ], ["phase", "metric", "value", "detail"]))
    print(rep.render())

    assert tput["qps"] >= 200.0, \
        f"sustained QPS gate failed: {tput['qps']:.0f} < 200"
    assert stream["stream_first_row_p95_s"] < \
        stream["buffered_full_p95_s"], \
        "streamed first-row p95 not below buffered full-result p95"
    assert nl["success_rate"] >= 0.90, \
        f"NL2SQL grounded-success gate failed: {nl['success_rate']:.2f}"

    save_result("bench_http", {
        "qps": tput["qps"],
        "queries": tput["queries"],
        "stream_first_row_p95_s": stream["stream_first_row_p95_s"],
        "buffered_full_p95_s": stream["buffered_full_p95_s"],
        "stream_first_row_p50_s": stream["stream_first_row_p50_s"],
        "buffered_full_p50_s": stream["buffered_full_p50_s"],
        "fidelity_queries": fidelity_checked,
        "nl2sql_success_rate": nl["success_rate"],
        "total_credits": rep.total_credits,
        "tenant_credit_sum": tenant_sum,
        "nl2sql_rejected_attempts": nl2sql.rejected_attempts,
    })
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
