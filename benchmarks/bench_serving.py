"""§2: Cortex-platform serving substrate — real JAX engine throughput.

Measures wall-clock throughput of the smoke-size inference engine under
(a) per-row submission vs batched submission, (b) 1 vs 2 replicas with
the scheduler, and (c) fault injection (retry overhead).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_table, save_result
from repro.inference.backend import SCORE, Request
from repro.inference.engine import JaxInferenceEngine
from repro.inference.scheduler import Scheduler


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def run(n_requests: int = 32):
    prompts = [f"request number {i}: is this row relevant?" for i in
               range(n_requests)]
    reqs = [Request(p, "proxy-8b", SCORE, request_id=i)
            for i, p in enumerate(prompts)]
    rows = []

    engine = JaxInferenceEngine("proxy-8b", smoke=True, max_batch=8)
    engine.submit_batch(reqs[:8])      # warm the jit cache
    dt_batched, _ = _timed(lambda: engine.submit_batch(reqs))
    dt_single, _ = _timed(lambda: [engine.submit_batch([r]) for r in reqs])
    rows.append({"config": "single-row submits", "requests": n_requests,
                 "seconds": round(dt_single, 3),
                 "req_per_s": round(n_requests / dt_single, 1)})
    rows.append({"config": "batched submits", "requests": n_requests,
                 "seconds": round(dt_batched, 3),
                 "req_per_s": round(n_requests / dt_batched, 1)})

    # scheduler with retry under injected failures
    sched = Scheduler(max_retries=2)
    flaky = JaxInferenceEngine("proxy-8b", smoke=True, max_batch=8,
                               failure_rate=0.5, seed=1)
    healthy = JaxInferenceEngine("proxy-8b", smoke=True, max_batch=8, seed=2)
    healthy.submit_batch(reqs[:8])
    sched.register(flaky)
    sched.register(healthy)
    dt_ft, _ = _timed(lambda: sched.submit(reqs))
    rows.append({"config": "scheduler + 50% flaky replica",
                 "requests": n_requests, "seconds": round(dt_ft, 3),
                 "req_per_s": round(n_requests / dt_ft, 1),
                 "retries": sched.retries})
    return rows


def main():
    rows = run()
    print("== §2: serving substrate throughput (real JAX engine, smoke) ==")
    print(fmt_table(rows, ["config", "requests", "seconds", "req_per_s",
                           "retries"]))
    save_result("bench_serving", {"rows": rows})
    return rows


if __name__ == "__main__":
    main()
