"""§2: Cortex-platform serving substrate — engine throughput + the
semantic-operator runtime.

Measures (a) per-row vs batched submission on the real JAX engine,
(b) scheduler fault tolerance under injected failures, and (c) the
eager vs pipelined AISQL execution paths over the calibrated simulator:
scheduler submits, dedup hits, and wall time for a multi-predicate
filter+classify query and for a repeated cascade query (the production
warm-cache case).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_table, save_result
from repro.core import AisqlEngine, Catalog, CascadeConfig, ExecConfig
from repro.data import datasets as D
from repro.inference.api import make_simulated_client
from repro.inference.backend import SCORE, Request
from repro.inference.engine import JaxInferenceEngine
from repro.inference.scheduler import Scheduler


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def run(n_requests: int = 32):
    prompts = [f"request number {i}: is this row relevant?" for i in
               range(n_requests)]
    reqs = [Request(p, "proxy-8b", SCORE, request_id=i)
            for i, p in enumerate(prompts)]
    rows = []

    engine = JaxInferenceEngine("proxy-8b", smoke=True, max_batch=8)
    engine.submit_batch(reqs[:8])      # warm the jit cache
    dt_batched, _ = _timed(lambda: engine.submit_batch(reqs))
    dt_single, _ = _timed(lambda: [engine.submit_batch([r]) for r in reqs])
    rows.append({"config": "single-row submits", "requests": n_requests,
                 "seconds": round(dt_single, 3),
                 "req_per_s": round(n_requests / dt_single, 1)})
    rows.append({"config": "batched submits", "requests": n_requests,
                 "seconds": round(dt_batched, 3),
                 "req_per_s": round(n_requests / dt_batched, 1)})

    # scheduler with retry under injected failures
    sched = Scheduler(max_retries=2)
    flaky = JaxInferenceEngine("proxy-8b", smoke=True, max_batch=8,
                               failure_rate=0.5, seed=1)
    healthy = JaxInferenceEngine("proxy-8b", smoke=True, max_batch=8, seed=2)
    healthy.submit_batch(reqs[:8])
    sched.register(flaky)
    sched.register(healthy)
    dt_ft, _ = _timed(lambda: sched.submit(reqs))
    rows.append({"config": "scheduler + 50% flaky replica",
                 "requests": n_requests, "seconds": round(dt_ft, 3),
                 "req_per_s": round(n_requests / dt_ft, 1),
                 "retries": sched.retries})
    return rows


_FILTER_SQL = (
    "SELECT r.id, AI_CLASSIFY(PROMPT('sentiment of {0}', r.text), "
    "['positive','negative']) AS sentiment "
    "FROM reviews AS r WHERE "
    "AI_FILTER(PROMPT('does {0} express positive sentiment?', r.text)) "
    "AND AI_FILTER(PROMPT('is {0} about a movie?', r.text))")

_CASCADE_SQL = ("SELECT * FROM ds AS d WHERE "
                "AI_FILTER(PROMPT('answers? {0}', d.text))")


def _pipeline_row(label, mode, engine, client, dt, rows_out):
    rep = engine.last_report
    pipe = rep.pipeline or {}
    return {
        "workload": label, "mode": mode, "rows": rows_out,
        "submits": client.scheduler.submits,
        "ai_calls": client.ai_calls,
        "dedup_hits": pipe.get("dedup_hits", 0),
        "credits": round(client.ai_credits, 5),
        "seconds": round(dt, 3),
    }


def run_aisql_pipeline(n_rows: int = 800):
    """Eager vs pipelined AISQL over the calibrated simulator."""
    out = []
    # -- workload 1: two AI filters + a classify projection --------------
    results = {}
    for mode, pipelined in (("eager", False), ("pipelined", True)):
        cat = Catalog({"reviews": D.cascade_table("IMDB", rows=n_rows)})
        client = make_simulated_client(pipelined=pipelined)
        eng = AisqlEngine(cat, client)
        t0 = time.perf_counter()
        res = eng.sql(_FILTER_SQL)
        dt = time.perf_counter() - t0
        results[mode] = sorted(res.column("r.id").tolist())
        out.append(_pipeline_row("filter+classify", mode, eng, client, dt,
                                 res.num_rows))
    assert results["eager"] == results["pipelined"], \
        "pipelined row set diverged from eager"
    # -- workload 2: cascade filter, query issued twice (warm cache) -----
    for mode, pipelined in (("eager", False), ("pipelined", True)):
        cat = Catalog({"ds": D.cascade_table("NQ", rows=n_rows)})
        client = make_simulated_client(pipelined=pipelined)
        eng = AisqlEngine(cat, client,
                          executor=ExecConfig(use_cascade=True,
                                              cascade=CascadeConfig(seed=0)))
        t0 = time.perf_counter()
        eng.sql(_CASCADE_SQL)
        res = eng.sql(_CASCADE_SQL)        # repeated production query
        dt = time.perf_counter() - t0
        pipe = (client.pipeline.stats.snapshot() if client.pipeline
                else {})
        row = _pipeline_row("cascade x2", mode, eng, client, dt,
                            res.num_rows)
        row["dedup_hits"] = pipe.get("dedup_hits", 0)
        out.append(row)
    return out


def main():
    rows = run()
    print("== §2: serving substrate throughput (real JAX engine, smoke) ==")
    print(fmt_table(rows, ["config", "requests", "seconds", "req_per_s",
                           "retries"]))
    aisql = run_aisql_pipeline()
    print("\n== semantic-operator runtime: eager vs pipelined AISQL ==")
    print(fmt_table(aisql, ["workload", "mode", "rows", "submits",
                            "ai_calls", "dedup_hits", "credits", "seconds"]))
    by = {(r["workload"], r["mode"]): r for r in aisql}
    fc_speed = (by[("filter+classify", "eager")]["submits"]
                / max(by[("filter+classify", "pipelined")]["submits"], 1))
    cc_speed = (by[("cascade x2", "eager")]["submits"]
                / max(by[("cascade x2", "pipelined")]["submits"], 1))
    print(f"\nscheduler submits: {fc_speed:.1f}x fewer (filter+classify), "
          f"{cc_speed:.1f}x fewer (repeated cascade); "
          f"dedup hits on cascade: "
          f"{by[('cascade x2', 'pipelined')]['dedup_hits']}")
    save_result("bench_serving", {"rows": rows, "aisql": aisql})
    return rows


if __name__ == "__main__":
    main()
