"""Million-row scale benchmark: chunked storage under a byte budget.

The storage tentpole's acceptance gates, measured end to end:

  1. a **1M-row AI_FILTER** (selective relational pre-filter, then
     semantic filter over the survivors) runs under a fixed tracked-byte
     budget — chunks spill and reload under LRU pressure, peak tracked
     bytes are reported — and returns **exactly the rows** (and bills
     exactly the credits) of the unbounded run, with **zero full-column
     materializations** on the big table;
  2. an **index-assisted semantic join** whose embedding store lives in
     spillable vector pages under a byte budget returns exactly the
     pairs of the unbudgeted store, with page spills engaged;
  3. the **workload replay** harness sustains ≥1000 seeded tenant
     sessions (``--quick``: 250) against a spill-budgeted catalog and
     reports QPS, p50/p95 latency, cross-query cache-hit rate and peak
     tracked bytes — with measurable cross-query sharing and zero
     failed queries.

``--quick`` shrinks the table to 100k rows for CI.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, Iterable, List, Optional

import numpy as np

from benchmarks.common import fmt_table, save_result
from repro.core import (AisqlEngine, Catalog, ExecConfig, OptimizerConfig,
                        SemIndexConfig)
from repro.data import datasets as D
from repro.inference.api import make_simulated_client
from repro.tables.chunked import ChunkedTable
from repro.tables.spill import SpillManager

_TOPICS = ("databases", "weather", "finance", "sports", "security",
           "travel", "cooking", "music")


def _event_batches(n: int, batch: int, seed: int
                   ) -> Iterable[Dict[str, list]]:
    """Generator of column batches — the 1M-row table is built without
    ever holding the full columns in memory."""
    rng = np.random.default_rng(seed)
    for lo in range(0, n, batch):
        hi = min(lo + batch, n)
        idx = np.arange(lo, hi)
        yield {
            "id": idx,
            "gid": idx % 1000,
            "val": rng.random(hi - lo),
            "cat": rng.choice(["a", "b", "c", "d"], hi - lo),
            "text": [f"[e:{i}] event log about "
                     f"{_TOPICS[i % len(_TOPICS)]} item {i}"
                     for i in range(lo, hi)],
            "_truth": rng.random(hi - lo) < 0.4,
            "_difficulty": np.full(hi - lo, 0.05),
        }


def _build_events(n: int, chunk_rows: int,
                  budget_bytes: Optional[int]) -> ChunkedTable:
    spill = SpillManager(budget_bytes=budget_bytes)
    return ChunkedTable.from_batches(
        _event_batches(n, chunk_rows, seed=7),
        types={"id": "int", "gid": "int", "val": "float", "cat": "str",
               "text": "str", "_truth": "bool", "_difficulty": "float"},
        name="events", chunk_rows=chunk_rows, spill=spill)


def _filter_at_scale(n: int, chunk_rows: int, budget: int, thr: float,
                     seed: int) -> List[Dict]:
    """Gate 1: the same selective AI_FILTER on an unbounded and a
    byte-budgeted store."""
    sql = (f"SELECT e.id, e.cat FROM events AS e WHERE e.val < {thr} "
           "AND AI_FILTER(PROMPT('is this event about databases? {0}', "
           "e.text))")
    runs = []
    for mode, budget_bytes in (("unbounded", None), ("budgeted", budget)):
        t0 = time.perf_counter()
        events = _build_events(n, chunk_rows, budget_bytes)
        build_s = time.perf_counter() - t0
        cat = Catalog({"events": events})
        client = make_simulated_client(pipelined=True, seed=seed)
        eng = AisqlEngine(cat, client, executor=ExecConfig(
            partitioned=True, partition_rows=chunk_rows,
            adaptive_reorder=False, pilot_rows=0))
        t0 = time.perf_counter()
        out = eng.sql(sql)
        query_s = time.perf_counter() - t0
        rep = eng.last_report
        runs.append({
            "mode": mode, "rows": out.num_rows,
            "ids": sorted(int(x) for x in out.column("e.id")),
            "calls": rep.ai_calls, "credits": round(rep.ai_credits, 6),
            "materializations": events.materializations,
            "build_s": round(build_s, 2), "query_s": round(query_s, 2),
            **{k: v for k, v in events.spill.stats().items()},
        })
    free, tight = runs
    assert free["ids"] == tight["ids"], \
        "byte budget changed the AI_FILTER result rows"
    assert free["credits"] == tight["credits"], \
        "byte budget changed billed credits"
    assert tight["spill_events"] > 0 and tight["reload_events"] > 0, \
        f"budget {budget} never forced a spill (peak " \
        f"{tight['peak_bytes']})"
    assert free["materializations"] == tight["materializations"] == 0, \
        "scale query materialized a full column on the big table"
    assert tight["peak_bytes"] > 0
    return runs


def _index_join_under_budget(seed: int) -> List[Dict]:
    """Gate 2: index-assisted semantic join with the embedding store in
    spillable vector pages."""
    spec = D.JoinSpec(
        name="SCALEJOIN", left_rows=120, right_rows=256, kind="category",
        labels_per_left=1.2, doc_words=40, label_words=4,
        fp_bias=0.05, fn_bias=0.1, cls_drop=0.35, cls_adds=0.0)
    sql = ("SELECT * FROM l JOIN r ON AI_FILTER(PROMPT("
           "'Document {0} is tagged with topic {1}', l.content, r.label))")
    runs = []
    # ~376 vectors at dim 64 (256 B each) in 8 KiB pages: a 20 KiB
    # budget keeps only ~2 pages resident, forcing constant eviction
    for mode, embed_budget in (("unbounded", None), ("budgeted", 20_000)):
        left, right, _ = D.join_tables(seed=seed, spec=spec)
        cat = Catalog({"l": left, "r": right})
        cfg = SemIndexConfig(impl="interpret", join_k=32, nlist=16,
                             nprobe=8, embed_budget_bytes=embed_budget,
                             embed_page_rows=32)
        client = make_simulated_client(seed=seed)
        eng = AisqlEngine(cat, client,
                          optimizer=OptimizerConfig(max_labels_per_call=50),
                          semindex=cfg)
        labels = [str(v) for v in right.column("label")]
        eng.semindex.ensure_index(
            client, "r.label", labels,
            metadata=[{"embed_anchor": u} for u in labels])
        out = eng.sql(sql)
        rep = eng.last_report
        assert "SemanticJoinIndex" in rep.plan, rep.plan
        pairs = sorted(zip((int(x) for x in out.column("l.id")),
                           (str(x) for x in out.column("r.label"))))
        stats = eng.semindex.store.spill_stats() or {}
        runs.append({"mode": mode, "pairs": pairs,
                     "rows": out.num_rows, "calls": rep.ai_calls,
                     "credits": round(rep.ai_credits, 6), **stats})
    free, tight = runs
    assert free["pairs"] == tight["pairs"], \
        "embedding-store byte budget changed the join result"
    assert tight["spill_events"] > 0, \
        "embed budget never forced a vector-page spill"
    return runs


def _replay_gate(sessions: int, seed: int) -> Dict:
    """Gate 3: sustained seeded tenant sessions over a spill-budgeted
    catalog; QPS, p95, cross-query hit rate, peak bytes."""
    sys.path.insert(0, "tools")
    from replay import TraceConfig, build_catalog, generate_trace, replay
    cfg = TraceConfig(seed=seed, sessions=sessions, tenants=8,
                      rows=2048, chunk_rows=256)
    trace = generate_trace(cfg)
    rep = replay(trace, build_catalog(cfg, budget_bytes=32_768),
                 workers=8, seed=seed)
    assert rep.sessions >= sessions
    assert rep.failed_queries == 0
    assert rep.qps > 0 and rep.latency_p95_s >= rep.latency_p50_s
    assert rep.cross_query_hit_rate > 0.15, \
        f"Zipf-hot trace produced no cross-query sharing " \
        f"({rep.cross_query_hit_rate:.1%})"
    assert rep.storage is not None and rep.storage["spill_events"] > 0
    assert rep.storage["peak_bytes"] > 0
    return {
        "queries": rep.queries, "sessions": rep.sessions,
        "tenants": rep.tenants, "wall_s": round(rep.wall_s, 2),
        "qps": round(rep.qps, 1),
        "p50_ms": round(rep.latency_p50_s * 1e3, 1),
        "p95_ms": round(rep.latency_p95_s * 1e3, 1),
        "dedup_hit_rate": round(rep.dedup_hit_rate, 4),
        "cross_query_hit_rate": round(rep.cross_query_hit_rate, 4),
        "total_credits": round(rep.total_credits, 6),
        "storage": rep.storage,
    }


def run(seed: int = 0, quick: bool = False):
    if quick:
        n, chunk_rows, budget, sessions = 100_000, 16_384, 3 << 20, 250
    else:
        n, chunk_rows, budget, sessions = 1_000_000, 65_536, 24 << 20, 1000
    thr = 2000 / n     # ~2000 survivor rows reach the AI filter

    filt = _filter_at_scale(n, chunk_rows, budget, thr, seed)
    join = _index_join_under_budget(seed)
    rply = _replay_gate(sessions, seed)
    return {"rows": n, "chunk_rows": chunk_rows, "budget_bytes": budget,
            "filter": filt, "join": join, "replay": rply}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="100k rows / 250 sessions (CI)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    s = run(seed=args.seed, quick=args.quick)

    print(f"== scale: {s['rows']} rows, chunk {s['chunk_rows']}, "
          f"budget {s['budget_bytes'] >> 20}MiB ==")
    cols = ["mode", "rows", "calls", "credits", "peak_bytes",
            "spill_events", "reload_events", "build_s", "query_s"]
    print(fmt_table([{k: r.get(k, "") for k in cols} for r in s["filter"]],
                    cols))
    print("AI_FILTER rows identical under budget; 0 materializations")
    jcols = ["mode", "rows", "calls", "credits", "peak_bytes",
             "spill_events", "reload_events"]
    print(fmt_table([{k: r.get(k, "") for k in jcols} for r in s["join"]],
                    jcols))
    print("index join pairs identical with paged embedding store")
    r = s["replay"]
    print(f"replay: {r['queries']} queries / {r['sessions']} sessions "
          f"/ {r['tenants']} tenants -> {r['qps']} qps, "
          f"p50 {r['p50_ms']}ms p95 {r['p95_ms']}ms, "
          f"cross-query hits {r['cross_query_hit_rate']:.1%}, "
          f"peak {r['storage']['peak_bytes']} bytes "
          f"({r['storage']['spill_events']} spills)")

    # results/*.json must stay digestible: drop the full id/pair lists
    slim = dict(s)
    slim["filter"] = [{k: v for k, v in r.items() if k != "ids"}
                      for r in s["filter"]]
    slim["join"] = [{k: v for k, v in r.items() if k != "pairs"}
                    for r in s["join"]]
    save_result("bench_scale", slim)
    return s


if __name__ == "__main__":
    main()
