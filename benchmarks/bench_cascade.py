"""Table 2 / Fig 11: adaptive model cascades on six boolean benchmarks.

Three configurations per dataset (paper §6.2):
  oracle-only (llama3.3-70B class), cascade (SUPG-IT), proxy-only (8B).
Reports execution time (modelled serving clock), speedup, F1/precision/
recall vs ground truth, and delegation rate.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table, model_clock, save_result
from repro.core import AisqlEngine, Catalog, CascadeConfig, ExecConfig
from repro.data import datasets as D
from repro.inference.api import make_simulated_client


def _run_one(name: str, mode: str, seed: int = 0):
    t = D.cascade_table(name, seed=seed)
    cat = Catalog({"ds": t})
    sql = ("SELECT * FROM ds AS d WHERE "
           f"AI_FILTER(PROMPT('{D.CASCADE_PREDICATES[name]}', d.text))")
    client = make_simulated_client(seed=seed)
    ec = ExecConfig()
    if mode == "cascade":
        ec = ExecConfig(use_cascade=True, cascade=CascadeConfig(seed=seed))
    if mode == "proxy":
        client.default_model = "proxy-8b"
    eng = AisqlEngine(cat, client, executor=ec)
    out = eng.sql(sql)
    ids = set(out.column("d.id").tolist())
    pred = np.array([i in ids for i in t.column("id")])
    m = D.binary_metrics(pred, t.column("_truth"))
    res = {"time_s": model_clock(client), **m,
           "oracle_calls": client.calls_by_model.get("oracle-70b", 0),
           "proxy_calls": client.calls_by_model.get("proxy-8b", 0)}
    if mode == "cascade" and eng.cascades:
        casc = list(eng.cascades.values())[0]
        res["delegation_rate"] = round(casc.stats.delegation_rate, 4)
        res["tau_low"] = round(casc.stats.tau_low, 4)
        res["tau_high"] = round(casc.stats.tau_high, 4)
    return res


def run(seed: int = 0):
    per_ds = []
    for name in D.CASCADE_DATASETS:
        r = {"dataset": name}
        res = {m: _run_one(name, m, seed) for m in
               ("oracle", "cascade", "proxy")}
        r["t_oracle"] = round(res["oracle"]["time_s"], 2)
        r["t_cascade"] = round(res["cascade"]["time_s"], 2)
        r["t_proxy"] = round(res["proxy"]["time_s"], 2)
        r["speedup"] = round(res["oracle"]["time_s"]
                             / res["cascade"]["time_s"], 2)
        r["f1_oracle"] = round(res["oracle"]["f1"], 3)
        r["f1_cascade"] = round(res["cascade"]["f1"], 3)
        r["f1_proxy"] = round(res["proxy"]["f1"], 3)
        r["f1_retained"] = round(res["cascade"]["f1"]
                                 / max(res["oracle"]["f1"], 1e-9), 3)
        r["delegation"] = res["cascade"].get("delegation_rate", 0)
        r["prec_cascade"] = round(res["cascade"]["precision"], 3)
        r["rec_cascade"] = round(res["cascade"]["recall"], 3)
        per_ds.append(r)
    mean = {
        "dataset": "MEAN",
        "t_oracle": round(np.mean([r["t_oracle"] for r in per_ds]), 2),
        "t_cascade": round(np.mean([r["t_cascade"] for r in per_ds]), 2),
        "t_proxy": round(np.mean([r["t_proxy"] for r in per_ds]), 2),
        "speedup": round(np.mean([r["t_oracle"] for r in per_ds])
                         / np.mean([r["t_cascade"] for r in per_ds]), 2),
        "f1_oracle": round(np.mean([r["f1_oracle"] for r in per_ds]), 3),
        "f1_cascade": round(np.mean([r["f1_cascade"] for r in per_ds]), 3),
        "f1_proxy": round(np.mean([r["f1_proxy"] for r in per_ds]), 3),
        "f1_retained": round(
            np.mean([r["f1_cascade"] for r in per_ds])
            / np.mean([r["f1_oracle"] for r in per_ds]), 3),
    }
    return per_ds + [mean]


def main():
    rows = run()
    print("== Table 2 / Fig 11: adaptive model cascades (6 datasets) ==")
    print(fmt_table(rows, ["dataset", "t_oracle", "t_cascade", "speedup",
                           "f1_oracle", "f1_cascade", "f1_retained",
                           "f1_proxy", "delegation"]))
    print("paper: 1.22-5.9x speedups, cascade retains ~95.7% of oracle F1")
    save_result("bench_cascade", {"rows": rows})
    return rows


if __name__ == "__main__":
    main()
